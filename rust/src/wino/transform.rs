//! Floating-point 2-D Winograd convolution — canonical (paper eq. 3) and
//! base-changed (paper eq. 4) evaluation pipelines.
//!
//! Both pipelines are algebraically identical; they differ only in the
//! order of floating-point operations, which is precisely what the paper
//! exploits: the base-changed pipeline routes the arithmetic through
//! better-conditioned intermediates, so the *rounding* (fp32 here, int8 in
//! `quant::qwino`) error shrinks.

use super::basis::{Base, BaseChange};
use super::matrix::Mat;
use super::toomcook::WinogradPlan;

/// Floating-point lowering of a [`WinogradPlan`] + [`BaseChange`]: all the
/// matrices of eq. 4 precomputed in f64 (and optionally rounded through f32
/// to model single-precision storage).
#[derive(Clone)]
pub struct WinoF {
    pub m: usize,
    pub r: usize,
    pub n: usize,
    pub base: Base,
    /// `A_P = P A` (N×m).
    pub a_p: Mat,
    /// `G_P = P G` (N×r).
    pub g_p: Mat,
    /// `B_Pᵀ = (P B)ᵀ = Bᵀ Pᵀ` (N×N).
    pub bt_p: Mat,
    /// `P⁻¹` (N×N).
    pub p_inv: Mat,
    /// `P⁻ᵀ` (N×N).
    pub p_inv_t: Mat,
    /// True when `P = I`, letting the hot path skip the base-change stages.
    pub identity_base: bool,
}

impl WinoF {
    /// Lower an exact plan into f64, conjugating the transforms by the
    /// base-change matrix `P` (`A_P = PA`, `G_P = PG`, `B_Pᵀ = BᵀPᵀ`).
    ///
    /// Both bases evaluate the same function — only the floating-point
    /// rounding route differs:
    ///
    /// ```
    /// use winoq::wino::basis::Base;
    /// use winoq::wino::conv::direct_correlate_2d;
    /// use winoq::wino::matrix::Mat;
    /// use winoq::wino::toomcook::WinogradPlan;
    /// use winoq::wino::transform::WinoF;
    ///
    /// let plan = WinogradPlan::new(4, 3);
    /// let x = Mat::from_rows(
    ///     (0..6).map(|i| (0..6).map(|j| ((5 * i + j) % 7) as f64).collect()).collect(),
    /// );
    /// let w = Mat::from_rows(vec![vec![1.0, 0.0, -1.0]; 3]);
    /// let direct = direct_correlate_2d(&x, &w);
    /// for base in [Base::Canonical, Base::Legendre] {
    ///     let wf = WinoF::new(&plan, base);
    ///     let y = wf.correlate_tile(&x, &w);
    ///     for i in 0..4 {
    ///         for j in 0..4 {
    ///             assert!((y[(i, j)] - direct[(i, j)]).abs() < 1e-10);
    ///         }
    ///     }
    /// }
    /// ```
    pub fn new(plan: &WinogradPlan, base: Base) -> WinoF {
        let bc = BaseChange::new(base, plan.n);
        let p = bc.p.to_f64();
        let p_inv = bc.p_inv.to_f64();
        let a = plan.a.to_f64();
        let g = plan.g.to_f64();
        let bt = plan.bt.to_f64();
        WinoF {
            m: plan.m,
            r: plan.r,
            n: plan.n,
            base,
            a_p: p.matmul(&a),
            g_p: p.matmul(&g),
            bt_p: bt.matmul(&p.transpose()),
            p_inv_t: p_inv.transpose(),
            p_inv,
            identity_base: bc.is_identity(),
        }
    }

    /// Round every transform matrix through f32 — models storing the
    /// transforms in single precision (as a deployed kernel would).
    pub fn through_f32(&self) -> WinoF {
        WinoF {
            a_p: self.a_p.through_f32(),
            g_p: self.g_p.through_f32(),
            bt_p: self.bt_p.through_f32(),
            p_inv: self.p_inv.through_f32(),
            p_inv_t: self.p_inv_t.through_f32(),
            ..self.clone()
        }
    }

    /// Weight transform: canonical `G W Gᵀ`, or through the base:
    /// `P⁻¹ (G_P W G_Pᵀ) P⁻ᵀ` (paper eq. 2). `w` is r×r; result N×N.
    pub fn transform_weights(&self, w: &Mat) -> Mat {
        assert_eq!((w.rows(), w.cols()), (self.r, self.r));
        let core = self.g_p.matmul(w).matmul(&self.g_p.transpose());
        if self.identity_base {
            core
        } else {
            self.p_inv.matmul(&core).matmul(&self.p_inv_t)
        }
    }

    /// Input transform: canonical `Bᵀ X B`, or `B_Pᵀ (P⁻ᵀ X P⁻¹) B_P`.
    /// `x` is N×N; result N×N.
    pub fn transform_input(&self, x: &Mat) -> Mat {
        assert_eq!((x.rows(), x.cols()), (self.n, self.n));
        if self.identity_base {
            self.bt_p.matmul(x).matmul(&self.bt_p.transpose())
        } else {
            let xp = self.p_inv_t.matmul(x).matmul(&self.p_inv);
            self.bt_p.matmul(&xp).matmul(&self.bt_p.transpose())
        }
    }

    /// Output transform: canonical `Aᵀ M A`, or `A_Pᵀ (P⁻ᵀ M P⁻¹) A_P`.
    /// `m_had` is N×N; result m×m.
    pub fn transform_output(&self, m_had: &Mat) -> Mat {
        assert_eq!((m_had.rows(), m_had.cols()), (self.n, self.n));
        let at = self.a_p.transpose();
        if self.identity_base {
            at.matmul(m_had).matmul(&self.a_p)
        } else {
            let mp = self.p_inv_t.matmul(m_had).matmul(&self.p_inv);
            at.matmul(&mp).matmul(&self.a_p)
        }
    }

    /// Full single-tile, single-channel 2-D Winograd correlation:
    /// `Y = out( in(X) ⊙ wt(W) )`, X N×N, W r×r, Y m×m.
    pub fn correlate_tile(&self, x: &Mat, w: &Mat) -> Mat {
        let xt = self.transform_input(x);
        let wt = self.transform_weights(w);
        let mut had = Mat::zeros(self.n, self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                had[(i, j)] = xt[(i, j)] * wt[(i, j)];
            }
        }
        self.transform_output(&had)
    }

    /// Multi-channel tile correlation: Hadamard products accumulated over
    /// `C` input channels before the single output transform — the layout
    /// every real Winograd conv layer uses (and where quantised accumulation
    /// error concentrates, per the paper's §5/§6 analysis).
    pub fn correlate_tile_multichannel(&self, xs: &[Mat], ws: &[Mat]) -> Mat {
        assert_eq!(xs.len(), ws.len());
        let mut acc = Mat::zeros(self.n, self.n);
        for (x, w) in xs.iter().zip(ws) {
            let xt = self.transform_input(x);
            let wt = self.transform_weights(w);
            for i in 0..self.n {
                for j in 0..self.n {
                    acc[(i, j)] += xt[(i, j)] * wt[(i, j)];
                }
            }
        }
        self.transform_output(&acc)
    }
}

#[cfg(test)]
mod tests {
    use super::super::conv::direct_correlate_2d;
    use super::*;

    fn prng_mat(seed: u64, rows: usize, cols: usize, scale: f64) -> Mat {
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64
                / (1u64 << 53) as f64;
            data.push((u * 2.0 - 1.0) * scale);
        }
        Mat::from_vec(rows, cols, data)
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                let d = (a[(i, j)] - b[(i, j)]).abs();
                assert!(
                    d <= tol,
                    "mismatch at ({i},{j}): {} vs {} (|Δ|={d})",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    #[test]
    fn f43_canonical_matches_direct() {
        let plan = WinogradPlan::new(4, 3);
        let wf = WinoF::new(&plan, Base::Canonical);
        for seed in 0..20 {
            let x = prng_mat(seed, 6, 6, 1.0);
            let w = prng_mat(seed + 100, 3, 3, 1.0);
            let direct = direct_correlate_2d(&x, &w);
            let wino = wf.correlate_tile(&x, &w);
            assert_close(&wino, &direct, 1e-10);
        }
    }

    #[test]
    fn f43_legendre_matches_direct() {
        let plan = WinogradPlan::new(4, 3);
        let wf = WinoF::new(&plan, Base::Legendre);
        for seed in 0..20 {
            let x = prng_mat(seed + 7, 6, 6, 1.0);
            let w = prng_mat(seed + 300, 3, 3, 1.0);
            assert_close(&wf.correlate_tile(&x, &w), &direct_correlate_2d(&x, &w), 1e-10);
        }
    }

    #[test]
    fn f43_chebyshev_matches_direct() {
        let plan = WinogradPlan::new(4, 3);
        let wf = WinoF::new(&plan, Base::Chebyshev);
        let x = prng_mat(42, 6, 6, 1.0);
        let w = prng_mat(43, 3, 3, 1.0);
        assert_close(&wf.correlate_tile(&x, &w), &direct_correlate_2d(&x, &w), 1e-10);
    }

    #[test]
    fn f23_and_f63_all_bases_match_direct() {
        for (m, r) in [(2usize, 3usize), (6, 3)] {
            let plan = WinogradPlan::new(m, r);
            for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
                let wf = WinoF::new(&plan, base);
                let x = prng_mat(m as u64 * 31, plan.n, plan.n, 1.0);
                let w = prng_mat(m as u64 * 37, r, r, 1.0);
                // f63 is numerically harsher — widen tolerance accordingly.
                assert_close(
                    &wf.correlate_tile(&x, &w),
                    &direct_correlate_2d(&x, &w),
                    1e-8,
                );
            }
        }
    }

    #[test]
    fn multichannel_matches_sum_of_tiles() {
        let plan = WinogradPlan::new(4, 3);
        let wf = WinoF::new(&plan, Base::Legendre);
        let xs: Vec<Mat> = (0..8).map(|c| prng_mat(c, 6, 6, 1.0)).collect();
        let ws: Vec<Mat> = (0..8).map(|c| prng_mat(c + 50, 3, 3, 1.0)).collect();
        let fused = wf.correlate_tile_multichannel(&xs, &ws);
        let mut summed = Mat::zeros(4, 4);
        for (x, w) in xs.iter().zip(&ws) {
            let y = direct_correlate_2d(x, w);
            for i in 0..4 {
                for j in 0..4 {
                    summed[(i, j)] += y[(i, j)];
                }
            }
        }
        assert_close(&fused, &summed, 1e-9);
    }

    #[test]
    fn legendre_pipeline_differs_in_rounding_not_value() {
        // Through f32-rounded transform matrices the two pipelines give
        // *different* results (different rounding) while both stay close to
        // the exact answer — the mechanism the paper exploits.
        let plan = WinogradPlan::new(4, 3);
        let can = WinoF::new(&plan, Base::Canonical).through_f32();
        let leg = WinoF::new(&plan, Base::Legendre).through_f32();
        let x = prng_mat(5, 6, 6, 10.0);
        let w = prng_mat(6, 3, 3, 1.0);
        let yc = can.correlate_tile(&x, &w);
        let yl = leg.correlate_tile(&x, &w);
        let direct = direct_correlate_2d(&x, &w);
        assert_close(&yc, &direct, 1e-3);
        assert_close(&yl, &direct, 1e-3);
    }
}
