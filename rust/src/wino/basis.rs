//! Polynomial-base-change matrices — the paper's §4.1 contribution.
//!
//! The Winograd transforms are evaluations/interpolations of polynomials
//! written, by default, in the canonical (monomial) base `1, x, x², …` — and
//! the associated Vandermonde matrices are notoriously ill-conditioned
//! (Pan 2016, paper ref [8]). Re-expressing the polynomials in a better
//! base — the paper uses *normalised (monic) Legendre* polynomials —
//! conditions the transforms.
//!
//! With `P` the base-change matrix (column `i` holds the canonical
//! coefficients of the i-th base polynomial) the paper defines
//! `G_P = PG`, `B_P = PB`, `A_P = PA` and computes (its eq. 4)
//!
//! ```text
//! Y = A_Pᵀ [ P⁻ᵀ [ (P⁻¹ (G_P W G_Pᵀ) P⁻ᵀ) ⊙ (B_Pᵀ (P⁻ᵀ X P⁻¹) B_P) ] P⁻¹ ] A_P
//! ```
//!
//! which is *algebraically identical* to the canonical algorithm — every `P`
//! cancels — but performs the floating-point/quantised arithmetic through
//! better-scaled intermediates. `P` is sparse (the paper counts 6 non-zero
//! *off-diagonal+diagonal-structure* entries at size 4×4 and 12 at 6×6 for
//! the strictly-lower part; see [`BaseChange::nnz_offdiag`]), so the extra
//! pre/post work is a handful of multiply-adds while the Hadamard stage —
//! the general-multiplication count — is untouched.

use super::matrix::RatMat;
use super::poly::Poly;
use super::rational::Rational;

/// Which polynomial base to run the Winograd transforms in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Base {
    /// Canonical monomial base — the plain Winograd/Toom-Cook algorithm.
    Canonical,
    /// Normalised (monic) Legendre polynomials — the paper's method ("L").
    Legendre,
    /// Monic Chebyshev (first kind) — mentioned by the paper as an
    /// alternative conditioning base; implemented for the ablation bench.
    Chebyshev,
}

impl Base {
    /// Every implemented base, in display order. This is the single table
    /// behind [`from_name`](Self::from_name) and [`names`](Self::names), so
    /// adding a base automatically extends name parsing, CLI error
    /// messages and the tuner's candidate grid.
    pub const ALL: [Base; 3] = [Base::Canonical, Base::Legendre, Base::Chebyshev];

    pub fn name(&self) -> &'static str {
        match self {
            Base::Canonical => "canonical",
            Base::Legendre => "legendre",
            Base::Chebyshev => "chebyshev",
        }
    }

    pub fn from_name(s: &str) -> Option<Base> {
        Base::ALL.into_iter().find(|b| b.name() == s)
    }

    /// The valid base names rendered `a|b|c` — what CLI errors print so an
    /// unknown `--base` tells the user the accepted spellings.
    pub fn names() -> String {
        Base::ALL.map(|b| b.name()).join("|")
    }
}

/// The base-change pair `(P, P⁻¹)` for an `n×n` transform, exact.
#[derive(Clone)]
pub struct BaseChange {
    pub base: Base,
    /// `P` — column `i` = canonical coefficients of base polynomial `i`.
    pub p: RatMat,
    /// `P⁻¹`, exact.
    pub p_inv: RatMat,
}

impl BaseChange {
    /// Build the base change for transform size `n`.
    ///
    /// `P` is exact (rational) and unit-upper-triangular, so `P⁻¹` always
    /// exists; the canonical base yields the identity.
    ///
    /// ```
    /// use winoq::wino::basis::{Base, BaseChange};
    ///
    /// let bc = BaseChange::new(Base::Legendre, 6);
    /// assert_eq!(bc.n(), 6);
    /// // Paper §4.1: the 6×6 Legendre P has 12 non-zeros (6 off-diagonal).
    /// assert_eq!(bc.p.nnz(), 12);
    /// assert_eq!(bc.nnz_offdiag(), 6);
    /// assert!(!bc.is_identity());
    /// assert!(BaseChange::new(Base::Canonical, 6).is_identity());
    /// ```
    pub fn new(base: Base, n: usize) -> BaseChange {
        let p = match base {
            Base::Canonical => RatMat::identity(n),
            Base::Legendre => poly_base_matrix(n, Poly::legendre_monic),
            Base::Chebyshev => poly_base_matrix(n, |k| {
                // T₀ and T₁ are already monic; monic() would panic on the
                // zero-degree edge only if T₀ were zero, which it is not.
                Poly::chebyshev_monic(k)
            }),
        };
        let p_inv = p.inverse();
        BaseChange { base, p, p_inv }
    }

    pub fn n(&self) -> usize {
        self.p.rows()
    }

    pub fn is_identity(&self) -> bool {
        self.p == RatMat::identity(self.n())
    }

    /// Non-zeros of `P` excluding the unit diagonal — the sparse extra
    /// multiply-adds the paper prices (6 at n=6 for Legendre's strictly
    /// lower-triangular part… see tests for the exact paper counts).
    pub fn nnz_offdiag(&self) -> usize {
        let n = self.n();
        let mut count = 0;
        for i in 0..n {
            for j in 0..n {
                if i != j && !self.p[(i, j)].is_zero() {
                    count += 1;
                }
            }
        }
        count
    }

    /// `Pᵀ` lowered to f64 — for comparison against the paper's printed
    /// matrices.
    pub fn p_transpose_f64(&self) -> Vec<Vec<f64>> {
        let pt = self.p.transpose();
        (0..pt.rows())
            .map(|i| (0..pt.cols()).map(|j| pt[(i, j)].to_f64()).collect())
            .collect()
    }
}

/// Build `P` (n×n) whose column `k` holds the canonical coefficients of the
/// k-th base polynomial (which must be monic of degree k, so `P` is
/// unit-upper-triangular in the (coeff-index, poly-index) layout).
fn poly_base_matrix(n: usize, family: impl Fn(usize) -> Poly) -> RatMat {
    let mut p = RatMat::zeros(n, n);
    for k in 0..n {
        let poly = family(k);
        assert_eq!(poly.degree(), k);
        assert!(poly.leading().is_one(), "base polynomial {k} not monic");
        for j in 0..=k {
            p[(j, k)] = poly.coeff(j);
        }
    }
    p
}

/// The paper's printed `Pᵀ` for n = 6 (its §4.1 matrix), kept as a golden
/// constant so construction changes can never silently drift from the paper.
pub fn paper_pt_6x6() -> RatMat {
    use super::rational::rat;
    let z = Rational::ZERO;
    let one = Rational::ONE;
    RatMat::from_rows(vec![
        vec![one, z, z, z, z, z],
        vec![z, one, z, z, z, z],
        vec![rat(-1, 3), z, one, z, z, z],
        vec![z, rat(-3, 5), z, one, z, z],
        vec![rat(3, 35), z, rat(-6, 7), z, one, z],
        vec![z, rat(5, 21), z, rat(-10, 9), z, one],
    ])
}

#[cfg(test)]
mod tests {
    use super::super::rational::rat;
    use super::*;

    #[test]
    fn canonical_is_identity() {
        let bc = BaseChange::new(Base::Canonical, 6);
        assert!(bc.is_identity());
        assert_eq!(bc.nnz_offdiag(), 0);
    }

    #[test]
    fn legendre_matches_paper_matrix() {
        // The paper prints Pᵀ for the 6×6 case; our construction must
        // reproduce it exactly.
        let bc = BaseChange::new(Base::Legendre, 6);
        assert_eq!(bc.p.transpose(), paper_pt_6x6());
    }

    #[test]
    fn p_inverse_roundtrips() {
        for base in [Base::Legendre, Base::Chebyshev] {
            for n in [2usize, 4, 6, 8] {
                let bc = BaseChange::new(base, n);
                assert_eq!(bc.p.matmul(&bc.p_inv), RatMat::identity(n));
                assert_eq!(bc.p_inv.matmul(&bc.p), RatMat::identity(n));
            }
        }
    }

    #[test]
    fn p_is_unit_triangular() {
        // Monic degree-k polynomials ⇒ P is unit upper triangular in
        // (coefficient row, polynomial column) layout; hence det P = 1 and
        // the base change is numerically benign by itself.
        let bc = BaseChange::new(Base::Legendre, 6);
        for i in 0..6 {
            assert!(bc.p[(i, i)].is_one());
            for j in 0..i {
                assert!(bc.p[(i, j)].is_zero(), "P[{i},{j}] should be 0");
            }
        }
    }

    #[test]
    fn paper_sparsity_counts() {
        // Paper §4.1: "The matrices of the size 4×4 and 6×6 include 6 and 12
        // non zero elements" — i.e. P beyond the identity structure: the 4×4
        // Legendre P has 2 off-diagonal nnz (total 6 nnz), the 6×6 has 6
        // off-diagonal (total 12 nnz).
        let bc4 = BaseChange::new(Base::Legendre, 4);
        assert_eq!(bc4.p.nnz(), 6);
        let bc6 = BaseChange::new(Base::Legendre, 6);
        assert_eq!(bc6.p.nnz(), 12);
    }

    #[test]
    fn legendre_specific_entries() {
        let bc = BaseChange::new(Base::Legendre, 6);
        // Column 4 = monic P4 = x⁴ − 6/7 x² + 3/35.
        assert_eq!(bc.p[(0, 4)], rat(3, 35));
        assert_eq!(bc.p[(2, 4)], rat(-6, 7));
        assert_eq!(bc.p[(4, 4)], rat(1, 1));
        // Column 5 = monic P5 = x⁵ − 10/9 x³ + 5/21 x.
        assert_eq!(bc.p[(1, 5)], rat(5, 21));
        assert_eq!(bc.p[(3, 5)], rat(-10, 9));
    }

    #[test]
    fn chebyshev_entries() {
        // Monic T2 = x² − 1/2, monic T3 = x³ − 3/4 x.
        let bc = BaseChange::new(Base::Chebyshev, 4);
        assert_eq!(bc.p[(0, 2)], rat(-1, 2));
        assert_eq!(bc.p[(1, 3)], rat(-3, 4));
    }

    #[test]
    fn base_names_roundtrip() {
        for b in Base::ALL {
            assert_eq!(Base::from_name(b.name()), Some(b));
        }
        assert_eq!(Base::from_name("hermite"), None);
    }

    #[test]
    fn names_lists_every_base() {
        let names = Base::names();
        assert_eq!(names, "canonical|legendre|chebyshev");
        for b in Base::ALL {
            assert!(names.contains(b.name()));
        }
    }
}
