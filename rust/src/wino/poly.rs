//! Polynomial arithmetic over exact rationals.
//!
//! Used to construct the Toom-Cook matrices (products of `(x - pᵢ)` root
//! polynomials, Lagrange interpolation denominators) and the orthogonal
//! polynomial families (Legendre, Chebyshev) whose change-of-base matrices
//! the paper uses to condition the Winograd transforms.

use super::rational::Rational;
use std::fmt;
use std::ops::{Add, Mul, Neg, Sub};

/// A polynomial with rational coefficients, `coeffs[i]` is the coefficient
/// of `x^i`. The zero polynomial is represented by an empty vector; all
/// other representations keep the leading coefficient non-zero.
#[derive(Clone, PartialEq, Eq)]
pub struct Poly {
    coeffs: Vec<Rational>,
}

impl Poly {
    pub fn zero() -> Self {
        Poly { coeffs: vec![] }
    }

    pub fn one() -> Self {
        Poly::constant(Rational::ONE)
    }

    pub fn constant(c: Rational) -> Self {
        if c.is_zero() {
            Poly::zero()
        } else {
            Poly { coeffs: vec![c] }
        }
    }

    /// The monomial `x`.
    pub fn x() -> Self {
        Poly { coeffs: vec![Rational::ZERO, Rational::ONE] }
    }

    /// `x - r` — linear root polynomial used by Toom-Cook's CRT moduli.
    pub fn linear_root(r: Rational) -> Self {
        Poly { coeffs: vec![-r, Rational::ONE] }
    }

    /// Build from low-to-high coefficients, trimming leading zeros.
    pub fn from_coeffs(coeffs: Vec<Rational>) -> Self {
        let mut p = Poly { coeffs };
        p.trim();
        p
    }

    fn trim(&mut self) {
        while matches!(self.coeffs.last(), Some(c) if c.is_zero()) {
            self.coeffs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.coeffs.is_empty()
    }

    /// Degree; the zero polynomial reports degree 0 by convention here
    /// (callers in this crate never branch on deg of zero).
    pub fn degree(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }

    /// Coefficient of `x^i` (zero beyond the stored length).
    pub fn coeff(&self, i: usize) -> Rational {
        self.coeffs.get(i).copied().unwrap_or(Rational::ZERO)
    }

    pub fn coeffs(&self) -> &[Rational] {
        &self.coeffs
    }

    pub fn leading(&self) -> Rational {
        self.coeffs.last().copied().unwrap_or(Rational::ZERO)
    }

    /// Evaluate at `x` by Horner's rule.
    pub fn eval(&self, x: Rational) -> Rational {
        let mut acc = Rational::ZERO;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    /// Multiply every coefficient by `s`.
    pub fn scale(&self, s: Rational) -> Self {
        if s.is_zero() {
            return Poly::zero();
        }
        Poly { coeffs: self.coeffs.iter().map(|&c| c * s).collect() }
    }

    /// Normalise so the leading coefficient is 1 (monic). Panics on zero.
    pub fn monic(&self) -> Self {
        assert!(!self.is_zero(), "monic of zero polynomial");
        self.scale(self.leading().recip())
    }

    /// Polynomial long division: returns `(quotient, remainder)`.
    pub fn div_rem(&self, divisor: &Poly) -> (Poly, Poly) {
        assert!(!divisor.is_zero(), "polynomial division by zero");
        if self.degree() < divisor.degree() || self.is_zero() {
            return (Poly::zero(), self.clone());
        }
        let mut rem = self.coeffs.clone();
        let dlead = divisor.leading();
        let ddeg = divisor.degree();
        let qdeg = self.degree() - ddeg;
        let mut quot = vec![Rational::ZERO; qdeg + 1];
        for qi in (0..=qdeg).rev() {
            let top = rem[qi + ddeg];
            if top.is_zero() {
                continue;
            }
            let q = top / dlead;
            quot[qi] = q;
            for (di, &dc) in divisor.coeffs.iter().enumerate() {
                rem[qi + di] = rem[qi + di] - q * dc;
            }
        }
        (Poly::from_coeffs(quot), Poly::from_coeffs(rem))
    }

    /// Product of `(x - p)` for each point — the Toom-Cook modulus `m(x)`.
    pub fn from_roots(roots: &[Rational]) -> Self {
        let mut acc = Poly::one();
        for &r in roots {
            acc = &acc * &Poly::linear_root(r);
        }
        acc
    }

    /// Formal derivative.
    pub fn derivative(&self) -> Poly {
        if self.coeffs.len() <= 1 {
            return Poly::zero();
        }
        let coeffs = self
            .coeffs
            .iter()
            .enumerate()
            .skip(1)
            .map(|(i, &c)| c * Rational::from_int(i as i128))
            .collect();
        Poly::from_coeffs(coeffs)
    }

    /// Legendre polynomial `P_n` by Bonnet recursion:
    /// `(n+1) P_{n+1} = (2n+1) x P_n − n P_{n−1}`.
    pub fn legendre(n: usize) -> Poly {
        let mut p0 = Poly::one();
        if n == 0 {
            return p0;
        }
        let mut p1 = Poly::x();
        for k in 1..n {
            let k = k as i128;
            let a = Rational::new(2 * k + 1, k + 1); // (2n+1)/(n+1)
            let b = Rational::new(k, k + 1); // n/(n+1)
            let next = &(&Poly::x() * &p1).scale(a) - &p0.scale(b);
            p0 = p1;
            p1 = next;
        }
        p1
    }

    /// "Normalised" Legendre polynomial of the paper: `P_n` rescaled so the
    /// leading coefficient is 1 (monic Legendre).
    pub fn legendre_monic(n: usize) -> Poly {
        Poly::legendre(n).monic()
    }

    /// Chebyshev polynomial of the first kind `T_n`:
    /// `T_{n+1} = 2x T_n − T_{n−1}`.
    pub fn chebyshev(n: usize) -> Poly {
        let mut t0 = Poly::one();
        if n == 0 {
            return t0;
        }
        let mut t1 = Poly::x();
        for _ in 1..n {
            let next = &(&Poly::x() * &t1).scale(Rational::from_int(2)) - &t0;
            t0 = t1;
            t1 = next;
        }
        t1
    }

    /// Monic Chebyshev (leading coefficient rescaled to 1).
    pub fn chebyshev_monic(n: usize) -> Poly {
        Poly::chebyshev(n).monic()
    }
}

impl fmt::Debug for Poly {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut first = true;
        for (i, c) in self.coeffs.iter().enumerate().rev() {
            if c.is_zero() {
                continue;
            }
            if !first {
                write!(f, " + ")?;
            }
            first = false;
            match i {
                0 => write!(f, "{c}")?,
                1 => write!(f, "{c}·x")?,
                _ => write!(f, "{c}·x^{i}")?,
            }
        }
        Ok(())
    }
}

impl Add for &Poly {
    type Output = Poly;
    fn add(self, rhs: &Poly) -> Poly {
        let n = self.coeffs.len().max(rhs.coeffs.len());
        let coeffs = (0..n).map(|i| self.coeff(i) + rhs.coeff(i)).collect();
        Poly::from_coeffs(coeffs)
    }
}

impl Sub for &Poly {
    type Output = Poly;
    fn sub(self, rhs: &Poly) -> Poly {
        let n = self.coeffs.len().max(rhs.coeffs.len());
        let coeffs = (0..n).map(|i| self.coeff(i) - rhs.coeff(i)).collect();
        Poly::from_coeffs(coeffs)
    }
}

impl Mul for &Poly {
    type Output = Poly;
    fn mul(self, rhs: &Poly) -> Poly {
        if self.is_zero() || rhs.is_zero() {
            return Poly::zero();
        }
        let mut coeffs =
            vec![Rational::ZERO; self.coeffs.len() + rhs.coeffs.len() - 1];
        for (i, &a) in self.coeffs.iter().enumerate() {
            if a.is_zero() {
                continue;
            }
            for (j, &b) in rhs.coeffs.iter().enumerate() {
                coeffs[i + j] += a * b;
            }
        }
        Poly::from_coeffs(coeffs)
    }
}

impl Neg for &Poly {
    type Output = Poly;
    fn neg(self) -> Poly {
        self.scale(-Rational::ONE)
    }
}

#[cfg(test)]
mod tests {
    use super::super::rational::rat;
    use super::*;

    #[test]
    fn from_roots_expands() {
        // (x)(x-1)(x+1) = x^3 - x
        let p = Poly::from_roots(&[rat(0, 1), rat(1, 1), rat(-1, 1)]);
        assert_eq!(p.coeff(0), rat(0, 1));
        assert_eq!(p.coeff(1), rat(-1, 1));
        assert_eq!(p.coeff(2), rat(0, 1));
        assert_eq!(p.coeff(3), rat(1, 1));
    }

    #[test]
    fn eval_horner() {
        let p = Poly::from_coeffs(vec![rat(1, 1), rat(2, 1), rat(3, 1)]); // 1+2x+3x^2
        assert_eq!(p.eval(rat(2, 1)), rat(17, 1));
        assert_eq!(p.eval(rat(-1, 2)), rat(3, 4));
    }

    #[test]
    fn div_rem_roundtrip() {
        let a = Poly::from_roots(&[rat(1, 1), rat(2, 1), rat(3, 1)]);
        let b = Poly::from_roots(&[rat(2, 1)]);
        let (q, r) = a.div_rem(&b);
        assert!(r.is_zero());
        assert_eq!(&(&q * &b) + &r, a);
    }

    #[test]
    fn div_rem_with_remainder() {
        // x^2 + 1 divided by x - 1 -> q = x + 1, r = 2
        let a = Poly::from_coeffs(vec![rat(1, 1), rat(0, 1), rat(1, 1)]);
        let b = Poly::linear_root(rat(1, 1));
        let (q, r) = a.div_rem(&b);
        assert_eq!(q, Poly::from_coeffs(vec![rat(1, 1), rat(1, 1)]));
        assert_eq!(r, Poly::constant(rat(2, 1)));
    }

    #[test]
    fn legendre_first_few() {
        // P0=1, P1=x, P2=(3x^2-1)/2, P3=(5x^3-3x)/2, P4=(35x^4-30x^2+3)/8
        assert_eq!(Poly::legendre(0), Poly::one());
        assert_eq!(Poly::legendre(1), Poly::x());
        let p2 = Poly::legendre(2);
        assert_eq!(p2.coeff(2), rat(3, 2));
        assert_eq!(p2.coeff(0), rat(-1, 2));
        let p4 = Poly::legendre(4);
        assert_eq!(p4.coeff(4), rat(35, 8));
        assert_eq!(p4.coeff(2), rat(-30, 8));
        assert_eq!(p4.coeff(0), rat(3, 8));
    }

    #[test]
    fn legendre_monic_matches_paper_entries() {
        // Monic P2 = x^2 - 1/3 — the paper's P^T row 3 is (-1/3, 0, 1, ...).
        let p2 = Poly::legendre_monic(2);
        assert_eq!(p2.coeff(0), rat(-1, 3));
        assert_eq!(p2.coeff(2), rat(1, 1));
        // Monic P3 = x^3 - 3/5 x — row 4 is (0, -3/5, 0, 1, ...).
        let p3 = Poly::legendre_monic(3);
        assert_eq!(p3.coeff(1), rat(-3, 5));
        // Monic P4 = x^4 - 6/7 x^2 + 3/35 — row 5 is (3/35, 0, -6/7, 0, 1, ...).
        let p4 = Poly::legendre_monic(4);
        assert_eq!(p4.coeff(0), rat(3, 35));
        assert_eq!(p4.coeff(2), rat(-6, 7));
        // Monic P5 = x^5 - 10/9 x^3 + 5/21 x — row 6 (0, 5/21, 0, -10/9, 0, 1).
        let p5 = Poly::legendre_monic(5);
        assert_eq!(p5.coeff(1), rat(5, 21));
        assert_eq!(p5.coeff(3), rat(-10, 9));
    }

    #[test]
    fn chebyshev_first_few() {
        // T2 = 2x^2 - 1, T3 = 4x^3 - 3x
        let t2 = Poly::chebyshev(2);
        assert_eq!(t2.coeff(2), rat(2, 1));
        assert_eq!(t2.coeff(0), rat(-1, 1));
        let t3 = Poly::chebyshev(3);
        assert_eq!(t3.coeff(3), rat(4, 1));
        assert_eq!(t3.coeff(1), rat(-3, 1));
    }

    #[test]
    fn legendre_orthogonality_spot_check() {
        // ∫_{-1}^{1} P2·P3 dx = 0: integrate the product exactly.
        let prod = &Poly::legendre(2) * &Poly::legendre(3);
        // Integral of x^k over [-1,1] is 0 for odd k, 2/(k+1) for even k.
        let mut integral = Rational::ZERO;
        for (k, &c) in prod.coeffs().iter().enumerate() {
            if k % 2 == 0 {
                integral += c * rat(2, (k + 1) as i128);
            }
        }
        assert!(integral.is_zero());
    }

    #[test]
    fn derivative() {
        let p = Poly::from_coeffs(vec![rat(1, 1), rat(2, 1), rat(3, 1)]);
        assert_eq!(
            p.derivative(),
            Poly::from_coeffs(vec![rat(2, 1), rat(6, 1)])
        );
        assert_eq!(Poly::one().derivative(), Poly::zero());
    }

    #[test]
    fn monic_scales_leading_to_one() {
        let p = Poly::from_coeffs(vec![rat(1, 1), rat(0, 1), rat(4, 1)]).monic();
        assert!(p.leading().is_one());
        assert_eq!(p.coeff(0), rat(1, 4));
    }
}
