//! Small dense matrices — exact (rational) and floating-point.
//!
//! The transform matrices involved are at most ~10×10, so everything here
//! is simple O(n³) dense code; clarity and exactness matter, not BLAS.

use super::rational::Rational;

/// Dense matrix with exact rational entries (row-major).
#[derive(Clone, PartialEq, Eq)]
pub struct RatMat {
    rows: usize,
    cols: usize,
    data: Vec<Rational>,
}

impl RatMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        RatMat { rows, cols, data: vec![Rational::ZERO; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = RatMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = Rational::ONE;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<Rational>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        RatMat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, i: usize) -> &[Rational] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> RatMat {
        let mut out = RatMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, rhs: &RatMat) -> RatMat {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        let mut out = RatMat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a.is_zero() {
                    continue;
                }
                for j in 0..rhs.cols {
                    let b = rhs[(k, j)];
                    if !b.is_zero() {
                        out[(i, j)] += a * b;
                    }
                }
            }
        }
        out
    }

    /// Exact inverse by Gauss-Jordan elimination with partial pivoting on
    /// exact rationals (pivot = first non-zero). Panics if singular.
    pub fn inverse(&self) -> RatMat {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = RatMat::identity(n);
        for col in 0..n {
            // Find a pivot row.
            let pivot = (col..n)
                .find(|&r| !a[(r, col)].is_zero())
                .expect("singular matrix in RatMat::inverse");
            if pivot != col {
                a.swap_rows(pivot, col);
                inv.swap_rows(pivot, col);
            }
            let p = a[(col, col)].recip();
            for j in 0..n {
                a[(col, j)] *= p;
                inv[(col, j)] *= p;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f.is_zero() {
                    continue;
                }
                for j in 0..n {
                    let ac = a[(col, j)];
                    let ic = inv[(col, j)];
                    a[(r, j)] -= f * ac;
                    inv[(r, j)] -= f * ic;
                }
            }
        }
        inv
    }

    fn swap_rows(&mut self, r1: usize, r2: usize) {
        if r1 == r2 {
            return;
        }
        for j in 0..self.cols {
            self.data.swap(r1 * self.cols + j, r2 * self.cols + j);
        }
    }

    /// Number of non-zero entries — the paper highlights P's sparsity
    /// (6 non-zeros at size 4, 12 at size 6... counting off-diagonal + diag).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|c| !c.is_zero()).count()
    }

    pub fn to_f64(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|c| c.to_f64()).collect(),
        }
    }

    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.data.iter().map(|c| c.to_f32()).collect()
    }
}

impl std::ops::Index<(usize, usize)> for RatMat {
    type Output = Rational;
    fn index(&self, (i, j): (usize, usize)) -> &Rational {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for RatMat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut Rational {
        &mut self.data[i * self.cols + j]
    }
}

impl std::fmt::Debug for RatMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for i in 0..self.rows {
            write!(f, "[")?;
            for j in 0..self.cols {
                if j > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{:>7}", format!("{}", self[(i, j)]))?;
            }
            writeln!(f, "]")?;
        }
        Ok(())
    }
}

/// Dense f64 matrix (row-major) — the floating-point shadow of `RatMat`,
/// used by the numerical-error experiments.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..rhs.cols {
                    out[(i, j)] += a * rhs[(k, j)];
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| {
                (0..self.cols).map(|j| self[(i, j)] * v[j]).sum::<f64>()
            })
            .collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest singular value via power iteration on `MᵀM`.
    pub fn sigma_max(&self) -> f64 {
        let mtm = self.transpose().matmul(self);
        let n = mtm.rows;
        let mut v = vec![1.0 / (n as f64).sqrt(); n];
        let mut lambda = 0.0f64;
        for _ in 0..500 {
            let w = mtm.matvec(&v);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm == 0.0 {
                return 0.0;
            }
            let next: Vec<f64> = w.iter().map(|x| x / norm).collect();
            let delta: f64 =
                next.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
            v = next;
            lambda = norm;
            if delta < 1e-14 {
                break;
            }
        }
        lambda.sqrt()
    }

    /// Smallest singular value via inverse power iteration (through an
    /// explicit inverse — fine at these sizes). Requires square invertible.
    pub fn sigma_min(&self) -> f64 {
        let inv = self.inverse_f64();
        let s = inv.sigma_max();
        if s == 0.0 {
            0.0
        } else {
            1.0 / s
        }
    }

    /// Spectral (2-norm) condition number κ₂ = σ_max/σ_min.
    pub fn condition_number(&self) -> f64 {
        self.sigma_max() / self.sigma_min()
    }

    /// f64 Gauss-Jordan inverse with partial pivoting.
    pub fn inverse_f64(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Mat::identity(n);
        for col in 0..n {
            let pivot = (col..n)
                .max_by(|&r1, &r2| {
                    a[(r1, col)]
                        .abs()
                        .partial_cmp(&a[(r2, col)].abs())
                        .unwrap()
                })
                .unwrap();
            assert!(a[(pivot, col)].abs() > 1e-300, "singular matrix");
            if pivot != col {
                a.swap_rows(pivot, col);
                inv.swap_rows(pivot, col);
            }
            let p = 1.0 / a[(col, col)];
            for j in 0..n {
                a[(col, j)] *= p;
                inv[(col, j)] *= p;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let ac = a[(col, j)];
                    let ic = inv[(col, j)];
                    a[(r, j)] -= f * ac;
                    inv[(r, j)] -= f * ic;
                }
            }
        }
        inv
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    fn swap_rows(&mut self, r1: usize, r2: usize) {
        if r1 == r2 {
            return;
        }
        for j in 0..self.cols {
            self.data.swap(r1 * self.cols + j, r2 * self.cols + j);
        }
    }

    /// Round-trip every entry through f32 — models the precision loss of
    /// storing the transform matrices in single precision.
    pub fn through_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f32 as f64).collect(),
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for i in 0..self.rows {
            write!(f, "[")?;
            for j in 0..self.cols {
                if j > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{:>10.5}", self[(i, j)])?;
            }
            writeln!(f, "]")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::rational::rat;
    use super::*;

    fn r(n: i128) -> Rational {
        Rational::from_int(n)
    }

    #[test]
    fn ratmat_identity_matmul() {
        let i3 = RatMat::identity(3);
        let m = RatMat::from_rows(vec![
            vec![r(1), r(2), r(3)],
            vec![r(4), r(5), r(6)],
            vec![r(7), r(8), r(10)],
        ]);
        assert_eq!(i3.matmul(&m), m);
        assert_eq!(m.matmul(&i3), m);
    }

    #[test]
    fn ratmat_inverse_roundtrip() {
        let m = RatMat::from_rows(vec![
            vec![r(1), r(2), r(3)],
            vec![r(4), r(5), r(6)],
            vec![r(7), r(8), r(10)],
        ]);
        let inv = m.inverse();
        assert_eq!(m.matmul(&inv), RatMat::identity(3));
        assert_eq!(inv.matmul(&m), RatMat::identity(3));
    }

    #[test]
    #[should_panic]
    fn ratmat_singular_inverse_panics() {
        let m = RatMat::from_rows(vec![
            vec![r(1), r(2)],
            vec![r(2), r(4)],
        ]);
        let _ = m.inverse();
    }

    #[test]
    fn ratmat_inverse_fractions() {
        let m = RatMat::from_rows(vec![
            vec![rat(1, 2), r(0)],
            vec![rat(1, 3), rat(2, 5)],
        ]);
        let inv = m.inverse();
        assert_eq!(m.matmul(&inv), RatMat::identity(2));
    }

    #[test]
    fn ratmat_transpose_involution() {
        let m = RatMat::from_rows(vec![vec![r(1), r(2), r(3)], vec![r(4), r(5), r(6)]]);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().rows(), 3);
    }

    #[test]
    fn ratmat_nnz() {
        let mut m = RatMat::zeros(3, 3);
        m[(0, 0)] = r(1);
        m[(2, 1)] = rat(3, 35);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn mat_matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn mat_inverse_roundtrip() {
        let m = Mat::from_rows(vec![
            vec![4.0, 7.0],
            vec![2.0, 6.0],
        ]);
        let inv = m.inverse_f64();
        let prod = m.matmul(&inv);
        for i in 0..2 {
            for j in 0..2 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn condition_number_identity_is_one() {
        let i4 = Mat::identity(4);
        let k = i4.condition_number();
        assert!((k - 1.0).abs() < 1e-6, "kappa={k}");
    }

    #[test]
    fn condition_number_diagonal() {
        // diag(10, 1) has kappa = 10.
        let m = Mat::from_rows(vec![vec![10.0, 0.0], vec![0.0, 1.0]]);
        let k = m.condition_number();
        assert!((k - 10.0).abs() < 1e-6, "kappa={k}");
    }

    #[test]
    fn sigma_max_known() {
        // [[3,0],[0,4]] -> sigma_max 4
        let m = Mat::from_rows(vec![vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((m.sigma_max() - 4.0).abs() < 1e-9);
        assert!((m.sigma_min() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ratmat_to_f64_matches() {
        let m = RatMat::from_rows(vec![vec![rat(1, 2), rat(-3, 4)]]);
        let f = m.to_f64();
        assert_eq!(f.data(), &[0.5, -0.75]);
    }
}
