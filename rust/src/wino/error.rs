//! Numerical-error analysis harness — experiment M1 (docs/ARCHITECTURE.md §Experiments).
//!
//! Quantifies the paper's motivating claims:
//! * §1: the Winograd error grows at least exponentially with the tile
//!   size (ill-conditioned Vandermonde transforms, Pan 2016);
//! * §4.1: changing to the Legendre base lowers both the condition numbers
//!   of the transforms and the end-to-end error.
//!
//! Error is measured against an f64 direct-convolution oracle while the
//! Winograd pipeline runs with f32-rounded transform matrices and
//! (optionally) f32-rounded intermediates.

use super::basis::Base;
use super::conv::direct_correlate_2d;
use super::matrix::Mat;
use super::toomcook::WinogradPlan;
use super::transform::WinoF;

/// Deterministic xorshift64* PRNG — uniform in [-scale, scale].
pub struct Prng(u64);

impl Prng {
    pub fn new(seed: u64) -> Prng {
        Prng(seed.wrapping_add(0x9E3779B97F4A7C15).max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn uniform(&mut self, scale: f64) -> f64 {
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        (u * 2.0 - 1.0) * scale
    }

    pub fn mat(&mut self, rows: usize, cols: usize, scale: f64) -> Mat {
        let data = (0..rows * cols).map(|_| self.uniform(scale)).collect();
        Mat::from_vec(rows, cols, data)
    }
}

/// One measured error statistic set.
#[derive(Clone, Copy, Debug)]
pub struct ErrorStats {
    /// Mean relative L2 error over trials.
    pub mean_rel_l2: f64,
    /// Max elementwise absolute error over all trials.
    pub max_abs: f64,
    /// Mean elementwise absolute error.
    pub mean_abs: f64,
}

/// Measure Winograd-vs-direct error for `F(m, 3)` in the given base, over
/// `trials` random tiles, with transform matrices rounded through f32
/// (mimicking a deployed fp32 kernel against an fp64 oracle).
pub fn measure_tile_error(
    m: usize,
    r: usize,
    base: Base,
    trials: usize,
    seed: u64,
) -> ErrorStats {
    let plan = WinogradPlan::new(m, r);
    let wf = WinoF::new(&plan, base).through_f32();
    let mut rng = Prng::new(seed);
    let mut sum_rel = 0.0;
    let mut max_abs = 0.0f64;
    let mut sum_abs = 0.0;
    let mut count_abs = 0usize;
    for _ in 0..trials {
        let x = rng.mat(plan.n, plan.n, 1.0);
        let w = rng.mat(r, r, 1.0);
        let oracle = direct_correlate_2d(&x, &w);
        let got = wino_f32_rounded(&wf, &x, &w);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..m {
            for j in 0..m {
                let d = (got[(i, j)] - oracle[(i, j)]).abs();
                num += d * d;
                den += oracle[(i, j)] * oracle[(i, j)];
                max_abs = max_abs.max(d);
                sum_abs += d;
                count_abs += 1;
            }
        }
        sum_rel += (num / den.max(1e-300)).sqrt();
    }
    ErrorStats {
        mean_rel_l2: sum_rel / trials as f64,
        max_abs,
        mean_abs: sum_abs / count_abs as f64,
    }
}

/// Run the tile pipeline with every intermediate rounded through f32 —
/// models a pure-f32 implementation (input/weight transform results, the
/// Hadamard products, and the output all pass through f32 storage).
fn wino_f32_rounded(wf: &WinoF, x: &Mat, w: &Mat) -> Mat {
    let xt = wf.transform_input(x).through_f32();
    let wt = wf.transform_weights(w).through_f32();
    let mut had = Mat::zeros(wf.n, wf.n);
    for i in 0..wf.n {
        for j in 0..wf.n {
            had[(i, j)] = xt[(i, j)] * wt[(i, j)];
        }
    }
    wf.transform_output(&had.through_f32()).through_f32()
}

/// Condition numbers κ₂ of the three (base-changed) transform matrices —
/// the quantity Pan 2016 ties the error growth to.
#[derive(Clone, Copy, Debug)]
pub struct ConditionNumbers {
    pub kappa_a: f64,
    pub kappa_g: f64,
    pub kappa_bt: f64,
}

/// κ₂ of the effective evaluation matrices for `F(m,r)` in `base`.
/// Non-square A_P/G_P use σ_max/σ_min through the Gram matrix.
pub fn condition_numbers(m: usize, r: usize, base: Base) -> ConditionNumbers {
    let plan = WinogradPlan::new(m, r);
    let wf = WinoF::new(&plan, base);
    ConditionNumbers {
        kappa_a: rect_condition(&wf.a_p),
        kappa_g: rect_condition(&wf.g_p),
        kappa_bt: wf.bt_p.condition_number(),
    }
}

/// Condition number for (possibly rectangular) matrices via the Gram
/// matrix: κ(M) = sqrt(κ₂(MᵀM)).
fn rect_condition(mat: &Mat) -> f64 {
    let gram = mat.transpose().matmul(mat);
    let smax = gram.sigma_max();
    let smin = gram.sigma_min();
    (smax / smin).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prng_is_deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn prng_uniform_in_range() {
        let mut rng = Prng::new(3);
        for _ in 0..1000 {
            let v = rng.uniform(2.5);
            assert!(v >= -2.5 && v <= 2.5);
        }
    }

    #[test]
    fn error_grows_with_tile_size() {
        // Paper §1: error increases (at least exponentially) with output
        // size — F(6,3) must be measurably worse than F(2,3) in f32.
        let e2 = measure_tile_error(2, 3, Base::Canonical, 200, 11);
        let e6 = measure_tile_error(6, 3, Base::Canonical, 200, 11);
        assert!(
            e6.mean_rel_l2 > e2.mean_rel_l2,
            "F(6,3) err {} !> F(2,3) err {}",
            e6.mean_rel_l2,
            e2.mean_rel_l2
        );
    }

    #[test]
    fn error_is_small_relative_to_signal() {
        let e = measure_tile_error(4, 3, Base::Canonical, 100, 5);
        assert!(e.mean_rel_l2 < 1e-3, "rel err unexpectedly large: {e:?}");
        assert!(e.mean_rel_l2 > 0.0, "f32 rounding must show up");
    }

    #[test]
    fn legendre_base_not_worse_f43() {
        // The headline mechanism: at F(4,3) the Legendre pipeline's error
        // must not exceed the canonical one's (paper shows strict gains at
        // int8; at f32 we assert non-inferiority with margin).
        let can = measure_tile_error(4, 3, Base::Canonical, 500, 23);
        let leg = measure_tile_error(4, 3, Base::Legendre, 500, 23);
        assert!(
            leg.mean_rel_l2 <= can.mean_rel_l2 * 1.5,
            "legendre {} vs canonical {}",
            leg.mean_rel_l2,
            can.mean_rel_l2
        );
    }

    #[test]
    fn condition_numbers_finite_and_ordered() {
        let c = condition_numbers(4, 3, Base::Canonical);
        assert!(c.kappa_bt.is_finite() && c.kappa_bt >= 1.0);
        assert!(c.kappa_a.is_finite() && c.kappa_a >= 1.0);
        assert!(c.kappa_g.is_finite() && c.kappa_g >= 1.0);
        // Condition worsens with tile size (Vandermonde pathology).
        let c6 = condition_numbers(6, 3, Base::Canonical);
        assert!(c6.kappa_bt > c.kappa_bt);
    }
}
