//! Exact rational arithmetic over `i128`.
//!
//! The Toom-Cook / Winograd transform matrices (G, Bᵀ, Aᵀ) and the
//! polynomial-base-change matrices (P, P⁻¹) are built from exact rational
//! entries — e.g. the paper's normalised-Legendre `Pᵀ` contains 3/35 and
//! 10/9 — and only lowered to f32/f64 at the very end. Constructing them in
//! floating point would contaminate the very error measurements the paper
//! is about, so everything in `wino::{poly,toomcook,basis}` runs on this
//! type.
//!
//! `i128` numerator/denominator is ample: the largest intermediate values in
//! the constructions we perform (tile sizes ≤ 10, Legendre degree ≤ 10)
//! stay far below 2⁶⁴ after reduction; every operation checks overflow.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Greatest common divisor (non-negative result).
fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// An exact rational number `num/den`, always stored reduced with `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rational {
    num: i128,
    den: i128,
}

impl Rational {
    pub const ZERO: Rational = Rational { num: 0, den: 1 };
    pub const ONE: Rational = Rational { num: 1, den: 1 };

    /// Construct `num/den`, reducing to lowest terms. Panics if `den == 0`.
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0, "Rational with zero denominator");
        let sign = if den < 0 { -1 } else { 1 };
        let g = gcd(num, den).max(1);
        Rational { num: sign * num / g, den: sign * den / g }
    }

    pub fn from_int(n: i128) -> Self {
        Rational { num: n, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_one(&self) -> bool {
        self.num == 1 && self.den == 1
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    pub fn abs(&self) -> Self {
        Rational { num: self.num.abs(), den: self.den }
    }

    /// Multiplicative inverse. Panics on zero.
    pub fn recip(&self) -> Self {
        assert!(self.num != 0, "reciprocal of zero");
        Rational::new(self.den, self.num)
    }

    pub fn pow(&self, mut e: u32) -> Self {
        let mut base = *self;
        let mut acc = Rational::ONE;
        while e > 0 {
            if e & 1 == 1 {
                acc = acc * base;
            }
            base = base * base;
            e >>= 1;
        }
        acc
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    pub fn to_f32(&self) -> f32 {
        self.to_f64() as f32
    }

    fn checked_add(self, rhs: Self) -> Option<Self> {
        // a/b + c/d = (a*d + c*b) / (b*d) — reduce via gcd of denominators
        // first to keep intermediates small.
        let g = gcd(self.den, rhs.den).max(1);
        let lhs_mul = rhs.den / g;
        let rhs_mul = self.den / g;
        let num = self
            .num
            .checked_mul(lhs_mul)?
            .checked_add(rhs.num.checked_mul(rhs_mul)?)?;
        let den = self.den.checked_mul(lhs_mul)?;
        Some(Rational::new(num, den))
    }

    fn checked_mul_impl(self, rhs: Self) -> Option<Self> {
        // Cross-reduce before multiplying to avoid overflow.
        let g1 = gcd(self.num, rhs.den).max(1);
        let g2 = gcd(rhs.num, self.den).max(1);
        let num = (self.num / g1).checked_mul(rhs.num / g2)?;
        let den = (self.den / g2).checked_mul(rhs.den / g1)?;
        Some(Rational::new(num, den))
    }
}

impl fmt::Debug for Rational {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Rational {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl Add for Rational {
    type Output = Rational;
    fn add(self, rhs: Self) -> Self {
        self.checked_add(rhs).expect("Rational add overflow")
    }
}

impl Sub for Rational {
    type Output = Rational;
    fn sub(self, rhs: Self) -> Self {
        self + (-rhs)
    }
}

impl Mul for Rational {
    type Output = Rational;
    fn mul(self, rhs: Self) -> Self {
        self.checked_mul_impl(rhs).expect("Rational mul overflow")
    }
}

impl Div for Rational {
    type Output = Rational;
    fn div(self, rhs: Self) -> Self {
        self * rhs.recip()
    }
}

impl Neg for Rational {
    type Output = Rational;
    fn neg(self) -> Self {
        Rational { num: -self.num, den: self.den }
    }
}

impl AddAssign for Rational {
    fn add_assign(&mut self, rhs: Self) {
        *self = *self + rhs;
    }
}

impl SubAssign for Rational {
    fn sub_assign(&mut self, rhs: Self) {
        *self = *self - rhs;
    }
}

impl MulAssign for Rational {
    fn mul_assign(&mut self, rhs: Self) {
        *self = *self * rhs;
    }
}

impl DivAssign for Rational {
    fn div_assign(&mut self, rhs: Self) {
        *self = *self / rhs;
    }
}

impl PartialOrd for Rational {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Rational {
    fn cmp(&self, other: &Self) -> Ordering {
        // a/b ? c/d  <=>  a*d ? c*b (both dens positive)
        (self.num * other.den).cmp(&(other.num * self.den))
    }
}

impl From<i128> for Rational {
    fn from(n: i128) -> Self {
        Rational::from_int(n)
    }
}

impl From<i64> for Rational {
    fn from(n: i64) -> Self {
        Rational::from_int(n as i128)
    }
}

impl From<i32> for Rational {
    fn from(n: i32) -> Self {
        Rational::from_int(n as i128)
    }
}

/// Convenience constructor: `rat(3, 35)` = 3/35.
pub fn rat(num: i128, den: i128) -> Rational {
    Rational::new(num, den)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduces_on_construction() {
        let r = Rational::new(6, -8);
        assert_eq!(r.num(), -3);
        assert_eq!(r.den(), 4);
    }

    #[test]
    fn zero_and_one() {
        assert!(Rational::ZERO.is_zero());
        assert!(Rational::ONE.is_one());
        assert_eq!(Rational::ZERO + Rational::ONE, Rational::ONE);
    }

    #[test]
    #[should_panic]
    fn zero_denominator_panics() {
        let _ = Rational::new(1, 0);
    }

    #[test]
    fn add_sub() {
        assert_eq!(rat(1, 3) + rat(1, 6), rat(1, 2));
        assert_eq!(rat(1, 2) - rat(1, 3), rat(1, 6));
        assert_eq!(rat(-1, 2) + rat(1, 2), Rational::ZERO);
    }

    #[test]
    fn mul_div() {
        assert_eq!(rat(2, 3) * rat(3, 4), rat(1, 2));
        assert_eq!(rat(1, 2) / rat(1, 4), rat(2, 1));
    }

    #[test]
    fn recip() {
        assert_eq!(rat(-3, 5).recip(), rat(-5, 3));
    }

    #[test]
    #[should_panic]
    fn recip_zero_panics() {
        let _ = Rational::ZERO.recip();
    }

    #[test]
    fn pow() {
        assert_eq!(rat(1, 2).pow(0), Rational::ONE);
        assert_eq!(rat(1, 2).pow(3), rat(1, 8));
        assert_eq!(rat(-2, 1).pow(3), rat(-8, 1));
    }

    #[test]
    fn ordering() {
        assert!(rat(1, 3) < rat(1, 2));
        assert!(rat(-1, 2) < rat(-1, 3));
        assert!(rat(3, 35) > Rational::ZERO);
    }

    #[test]
    fn to_float() {
        assert!((rat(3, 35).to_f64() - 0.08571428571428572).abs() < 1e-15);
        assert_eq!(rat(10, 9).to_f32(), (10.0f64 / 9.0) as f32);
    }

    #[test]
    fn cross_reduction_avoids_overflow() {
        // (big/1) * (1/big) must not overflow even though num*num would.
        let big = i128::MAX / 2;
        let a = Rational::new(big, 1);
        let b = Rational::new(1, big);
        assert_eq!(a * b, Rational::ONE);
    }

    #[test]
    fn display_forms() {
        assert_eq!(format!("{}", rat(3, 35)), "3/35");
        assert_eq!(format!("{}", rat(4, 2)), "2");
        assert_eq!(format!("{}", rat(-1, 3)), "-1/3");
    }
}
