//! Winograd/Toom-Cook substrate: exact matrix construction, polynomial
//! bases, floating-point pipelines, and error analysis.
//!
//! This module is the mathematical core of the paper's contribution — see
//! docs/ARCHITECTURE.md for how each submodule maps to the paper.

pub mod basis;
pub mod conv;
pub mod error;
pub mod matrix;
pub mod poly;
pub mod rational;
pub mod toomcook;
pub mod transform;

pub use basis::{Base, BaseChange};
pub use matrix::{Mat, RatMat};
pub use rational::Rational;
pub use toomcook::{Point, WinogradPlan};
pub use transform::WinoF;
