//! Toom-Cook / Winograd convolution matrix construction, in exact arithmetic.
//!
//! For `F(m, r)` — m correlation outputs of an r-tap filter over an
//! `N = m + r − 1` input tile — the algorithm is
//!
//! ```text
//! Y = Aᵀ [ (G g) ⊙ (Bᵀ d) ]          (1-D)
//! Y = Aᵀ [ (G W Gᵀ) ⊙ (Bᵀ X B) ] A    (2-D)
//! ```
//!
//! Derivation used here (Toom–Cook + Matrix Exchange, as in the paper's
//! refs [1,2,11]): evaluate the filter polynomial `g(x)` and the
//! linear-convolution operand at `N` interpolation points (the last one may
//! be the point at infinity, contributing the leading coefficient), multiply
//! pointwise, interpolate back. With
//!
//! * `V` — the generalised `N×N` Vandermonde over the points (∞ row = e_N),
//! * `V_r`, `V_m` — its first `r` / `m` columns,
//!
//! the linear convolution of `u` (len m) by `g` is
//! `s = V⁻¹ [(V_r g) ⊙ (V_m u)]`, and the Matrix Exchange Theorem
//! transposes the `u ↦ s` map into the correlation map, giving
//!
//! * `A = V_m`              (N×m)
//! * `G = F⁻¹ V_r`          (N×r),  `F = diag(Nᵢ)`, `Nᵢ = Πₖ≠ᵢ(pᵢ−pₖ)`
//! * `Bᵀ = F V⁻ᵀ`           (N×N)
//!
//! The diagonal `F` rebalancing (allowed because `(Fa)⊙(F⁻¹b) = a⊙b`) is the
//! standard convention that makes `Bᵀ` integer-valued for the classic point
//! sets — exactly the matrices of Lavin & Gray / the paper's Fig. 1.
//!
//! Everything is exact (`Rational`); `WinogradPlan::exact()` is
//! property-tested against direct correlation in `tests` below.

use super::matrix::RatMat;
use super::rational::{rat, Rational};

/// An interpolation point: finite rational or the point at infinity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Point {
    Finite(Rational),
    Infinity,
}

impl Point {
    pub fn finite(num: i128, den: i128) -> Point {
        Point::Finite(rat(num, den))
    }
}

/// The canonical interpolation-point ladder used throughout the literature
/// (and by the paper for F(4,3)): `0, 1, −1, ½, −½, 2, −2, ¼, −¼, 4, −4, …`
/// with the point at infinity last.
///
/// `n` is the total number of points including infinity.
pub fn standard_points(n: usize) -> Vec<Point> {
    assert!(n >= 1);
    let ladder = [
        (0i128, 1i128),
        (1, 1),
        (-1, 1),
        (1, 2),
        (-1, 2),
        (2, 1),
        (-2, 1),
        (1, 4),
        (-1, 4),
        (4, 1),
        (-4, 1),
        (3, 4),
        (-3, 4),
    ];
    assert!(n - 1 <= ladder.len(), "point ladder exhausted for n={n}");
    let mut pts: Vec<Point> =
        ladder[..n - 1].iter().map(|&(a, b)| Point::finite(a, b)).collect();
    pts.push(Point::Infinity);
    pts
}

/// A complete Winograd/Toom-Cook plan for `F(m, r)`: the exact transform
/// matrices plus cost metadata.
#[derive(Clone)]
pub struct WinogradPlan {
    /// Output tile size (per dimension).
    pub m: usize,
    /// Kernel size (per dimension).
    pub r: usize,
    /// Input tile size `N = m + r − 1`.
    pub n: usize,
    /// Interpolation points (len N).
    pub points: Vec<Point>,
    /// `A` — N×m output-side evaluation matrix (apply as `Aᵀ · `).
    pub a: RatMat,
    /// `G` — N×r weight transform.
    pub g: RatMat,
    /// `Bᵀ` — N×N input transform (apply as `Bᵀ · d`).
    pub bt: RatMat,
}

impl WinogradPlan {
    /// Build the plan for `F(m, r)` with the standard point ladder.
    ///
    /// The construction is exact, so the resulting algorithm reproduces
    /// direct correlation identically on rational inputs:
    ///
    /// ```
    /// use winoq::wino::rational::Rational;
    /// use winoq::wino::toomcook::WinogradPlan;
    ///
    /// let plan = WinogradPlan::new(2, 3); // F(2, 3): N = 4 input points
    /// assert_eq!(plan.n, 4);
    /// let r = Rational::from_int;
    /// let g = [r(1), r(2), r(3)];
    /// let d = [r(1), r(0), r(-1), r(2)];
    /// // direct correlation: y[t] = Σ_j g[j]·d[t+j] = [-2, 4]
    /// assert_eq!(plan.correlate_exact(&g, &d), vec![r(-2), r(4)]);
    /// ```
    pub fn new(m: usize, r: usize) -> WinogradPlan {
        let n = m + r - 1;
        Self::with_points(m, r, standard_points(n))
    }

    /// Build the plan for `F(m, r)` over explicit interpolation points.
    /// Points must be pairwise distinct; at most one `Infinity`, and if
    /// present it must be the last point.
    pub fn with_points(m: usize, r: usize, points: Vec<Point>) -> WinogradPlan {
        let n = m + r - 1;
        assert_eq!(points.len(), n, "need N = m+r-1 = {n} points");
        for (i, p) in points.iter().enumerate() {
            if matches!(p, Point::Infinity) {
                assert_eq!(i, n - 1, "Infinity must be the last point");
            }
        }
        // Distinctness of finite points.
        let finite: Vec<Rational> = points
            .iter()
            .filter_map(|p| match p {
                Point::Finite(v) => Some(*v),
                Point::Infinity => None,
            })
            .collect();
        for i in 0..finite.len() {
            for j in (i + 1)..finite.len() {
                assert!(finite[i] != finite[j], "duplicate interpolation point");
            }
        }

        let has_inf = matches!(points.last(), Some(Point::Infinity));

        // Generalised Vandermonde V (N×N): finite row i = [1, p, …, p^{N−1}],
        // infinity row = e_{N−1} (leading coefficient of the degree-(N−1)
        // product polynomial).
        let mut v = RatMat::zeros(n, n);
        for (i, p) in points.iter().enumerate() {
            match p {
                Point::Finite(pv) => {
                    for j in 0..n {
                        v[(i, j)] = pv.pow(j as u32);
                    }
                }
                Point::Infinity => {
                    v[(i, n - 1)] = Rational::ONE;
                }
            }
        }

        // A = V_m, pre-scale G0 = V_r.
        let mut a = RatMat::zeros(n, m);
        let mut g = RatMat::zeros(n, r);
        for (i, p) in points.iter().enumerate() {
            match p {
                Point::Finite(pv) => {
                    for t in 0..m {
                        a[(i, t)] = pv.pow(t as u32);
                    }
                    for j in 0..r {
                        g[(i, j)] = pv.pow(j as u32);
                    }
                }
                Point::Infinity => {
                    a[(i, m - 1)] = Rational::ONE;
                    g[(i, r - 1)] = Rational::ONE;
                }
            }
        }

        // F = diag(Nᵢ) over finite points (and 1 for the ∞ row): the
        // Lagrange denominators Nᵢ = Πₖ≠ᵢ (pᵢ − pₖ).
        let mut f = vec![Rational::ONE; n];
        let n_finite = finite.len();
        for i in 0..n_finite {
            let mut prod = Rational::ONE;
            for k in 0..n_finite {
                if k != i {
                    prod *= finite[i] - finite[k];
                }
            }
            f[i] = prod;
        }
        debug_assert!(has_inf || finite.len() == n);

        // G = F⁻¹ V_r ;  Bᵀ = F V⁻ᵀ.
        for i in 0..n {
            let inv = f[i].recip();
            for j in 0..r {
                g[(i, j)] *= inv;
            }
        }
        let v_inv_t = v.inverse().transpose();
        let mut bt = RatMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                bt[(i, j)] = f[i] * v_inv_t[(i, j)];
            }
        }

        WinogradPlan { m, r, n, points, a, g, bt }
    }

    /// Exact 1-D Winograd correlation: `Y = Aᵀ[(G g) ⊙ (Bᵀ d)]`.
    /// `g` has len r, `d` len N; returns len m.
    pub fn correlate_exact(&self, g: &[Rational], d: &[Rational]) -> Vec<Rational> {
        assert_eq!(g.len(), self.r);
        assert_eq!(d.len(), self.n);
        let gt: Vec<Rational> = (0..self.n)
            .map(|i| (0..self.r).map(|j| self.g[(i, j)] * g[j]).fold(Rational::ZERO, |a, b| a + b))
            .collect();
        let dt: Vec<Rational> = (0..self.n)
            .map(|i| (0..self.n).map(|j| self.bt[(i, j)] * d[j]).fold(Rational::ZERO, |a, b| a + b))
            .collect();
        let had: Vec<Rational> = gt.iter().zip(&dt).map(|(&a, &b)| a * b).collect();
        (0..self.m)
            .map(|t| {
                (0..self.n)
                    .map(|i| self.a[(i, t)] * had[i])
                    .fold(Rational::ZERO, |a, b| a + b)
            })
            .collect()
    }

    /// Number of general multiplications per 1-D output point: `N/m`.
    pub fn mults_per_output_1d(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// General multiplications per 2-D output point: `N²/m²`
    /// (paper §1/§2: 2.25 for F(4×4, 3×3) vs 9 for direct 3×3).
    ///
    /// ```
    /// let plan = winoq::wino::toomcook::WinogradPlan::new(4, 3);
    /// assert_eq!(plan.mults_per_output_2d(), 2.25);
    /// ```
    pub fn mults_per_output_2d(&self) -> f64 {
        let n = self.n as f64;
        let m = self.m as f64;
        (n * n) / (m * m)
    }
}

/// Cost model for one 2-D Winograd layer application — used by the
/// transform-cost bench (experiment M2, docs/ARCHITECTURE.md §Experiments).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransformCost {
    /// General (Hadamard-stage) multiplications per output point.
    pub general_mults_per_output: f64,
    /// Scalar multiply-adds in the input transform, per input tile.
    pub input_transform_madds: usize,
    /// Scalar multiply-adds in the output transform, per tile.
    pub output_transform_madds: usize,
    /// Scalar multiply-adds in the weight transform, per filter (amortised
    /// across the whole feature map, so usually negligible).
    pub weight_transform_madds: usize,
}

impl WinogradPlan {
    /// Transform cost of the plain (canonical-base) 2-D algorithm.
    /// A two-sided transform `M X Mᵀ` costs ≈ `nnz(M)` multiply-adds per
    /// column on each side, so sparsity of the matrices directly prices it.
    pub fn cost_canonical(&self) -> TransformCost {
        // Input: Bᵀ X B, X is N×N → 2 matmuls of N×N by N×N with sparsity
        // nnz(Bᵀ): cost ≈ nnz(Bᵀ)·N per side.
        let bt_madds = 2 * self.bt.nnz() * self.n;
        // Output: Aᵀ M A, M is N×N, Aᵀ is m×N: nnz(A)·N + nnz(A)·m.
        let at_madds = self.a.nnz() * self.n + self.a.nnz() * self.m;
        // Weights: G W Gᵀ, W is r×r: nnz(G)·r + nnz(G)·N.
        let g_madds = self.g.nnz() * self.r + self.g.nnz() * self.n;
        TransformCost {
            general_mults_per_output: self.mults_per_output_2d(),
            input_transform_madds: bt_madds,
            output_transform_madds: at_madds,
            weight_transform_madds: g_madds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::rational::rat;
    use super::*;

    /// Direct (oracle) correlation: Y_t = Σ_j g_j d_{t+j}.
    fn direct_corr(g: &[Rational], d: &[Rational], m: usize) -> Vec<Rational> {
        (0..m)
            .map(|t| {
                g.iter()
                    .enumerate()
                    .map(|(j, &gj)| gj * d[t + j])
                    .fold(Rational::ZERO, |a, b| a + b)
            })
            .collect()
    }

    fn pseudorandom_rationals(seed: u64, n: usize) -> Vec<Rational> {
        // xorshift64* — deterministic small rationals in [-8, 8] with
        // denominators in {1,2,4}.
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            let v = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 33) as i128;
            let num = (v % 17) - 8;
            let den = [1i128, 2, 4][(v % 3).unsigned_abs() as usize % 3];
            out.push(rat(num, den));
        }
        out
    }

    #[test]
    fn f23_matches_direct_exactly() {
        let plan = WinogradPlan::new(2, 3);
        assert_eq!(plan.n, 4);
        for seed in 0..50 {
            let g = pseudorandom_rationals(seed, 3);
            let d = pseudorandom_rationals(seed + 1000, 4);
            assert_eq!(plan.correlate_exact(&g, &d), direct_corr(&g, &d, 2));
        }
    }

    #[test]
    fn f43_matches_direct_exactly() {
        let plan = WinogradPlan::new(4, 3);
        assert_eq!(plan.n, 6);
        for seed in 0..50 {
            let g = pseudorandom_rationals(seed, 3);
            let d = pseudorandom_rationals(seed + 91, 6);
            assert_eq!(plan.correlate_exact(&g, &d), direct_corr(&g, &d, 4));
        }
    }

    #[test]
    fn f63_matches_direct_exactly() {
        let plan = WinogradPlan::new(6, 3);
        assert_eq!(plan.n, 8);
        for seed in 0..25 {
            let g = pseudorandom_rationals(seed, 3);
            let d = pseudorandom_rationals(seed + 7, 8);
            assert_eq!(plan.correlate_exact(&g, &d), direct_corr(&g, &d, 6));
        }
    }

    #[test]
    fn f25_matches_direct_exactly() {
        // Different kernel size exercises the V_r slicing.
        let plan = WinogradPlan::new(2, 5);
        assert_eq!(plan.n, 6);
        for seed in 0..25 {
            let g = pseudorandom_rationals(seed, 5);
            let d = pseudorandom_rationals(seed + 3, 6);
            assert_eq!(plan.correlate_exact(&g, &d), direct_corr(&g, &d, 2));
        }
    }

    #[test]
    fn all_finite_points_also_exact() {
        // Without the infinity point the plain Vandermonde path is used.
        let pts = vec![
            Point::finite(0, 1),
            Point::finite(1, 1),
            Point::finite(-1, 1),
            Point::finite(2, 1),
        ];
        let plan = WinogradPlan::with_points(2, 3, pts);
        for seed in 0..25 {
            let g = pseudorandom_rationals(seed, 3);
            let d = pseudorandom_rationals(seed + 13, 4);
            assert_eq!(plan.correlate_exact(&g, &d), direct_corr(&g, &d, 2));
        }
    }

    #[test]
    fn f23_bt_is_integer_valued() {
        // The classic F(2,3) Bᵀ is the integer matrix
        // [[1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]] up to row signs that
        // depend on the F convention; with F=diag(Nᵢ) all entries must be
        // integers.
        let plan = WinogradPlan::new(2, 3);
        for i in 0..plan.n {
            for j in 0..plan.n {
                assert!(
                    plan.bt[(i, j)].is_integer(),
                    "Bᵀ[{i},{j}] = {} not integer",
                    plan.bt[(i, j)]
                );
            }
        }
    }

    #[test]
    fn f43_shapes() {
        let plan = WinogradPlan::new(4, 3);
        assert_eq!((plan.a.rows(), plan.a.cols()), (6, 4));
        assert_eq!((plan.g.rows(), plan.g.cols()), (6, 3));
        assert_eq!((plan.bt.rows(), plan.bt.cols()), (6, 6));
    }

    #[test]
    fn mult_counts_match_paper() {
        // Paper §2: F(4×4, 3×3) needs 2.25 general mults per output point
        // (vs 9 for direct 3×3); Meng & Brothers' superlinear variant: 3.06.
        let plan = WinogradPlan::new(4, 3);
        assert!((plan.mults_per_output_2d() - 2.25).abs() < 1e-12);
        let f23 = WinogradPlan::new(2, 3);
        assert!((f23.mults_per_output_2d() - 4.0).abs() < 1e-12);
        let f63 = WinogradPlan::new(6, 3);
        assert!((f63.mults_per_output_2d() - (64.0 / 36.0)).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn duplicate_points_rejected() {
        let pts = vec![
            Point::finite(1, 1),
            Point::finite(1, 1),
            Point::finite(0, 1),
            Point::Infinity,
        ];
        let _ = WinogradPlan::with_points(2, 3, pts);
    }

    #[test]
    #[should_panic]
    fn infinity_not_last_rejected() {
        let pts = vec![
            Point::Infinity,
            Point::finite(1, 1),
            Point::finite(0, 1),
            Point::finite(-1, 1),
        ];
        let _ = WinogradPlan::with_points(2, 3, pts);
    }

    #[test]
    fn standard_points_ladder() {
        let pts = standard_points(6);
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], Point::finite(0, 1));
        assert_eq!(pts[3], Point::finite(1, 2));
        assert_eq!(pts[5], Point::Infinity);
    }

    #[test]
    fn cost_canonical_positive() {
        let c = WinogradPlan::new(4, 3).cost_canonical();
        assert!(c.input_transform_madds > 0);
        assert!(c.output_transform_madds > 0);
        assert!(c.weight_transform_madds > 0);
        assert!((c.general_mults_per_output - 2.25).abs() < 1e-12);
    }

    #[test]
    fn hadamard_rebalance_invariance() {
        // Multiplying G rows by s and Bᵀ rows by 1/s must not change the
        // result — the diagonal-rescale freedom the construction relies on.
        let plan = WinogradPlan::new(4, 3);
        let mut g2 = plan.g.clone();
        let mut bt2 = plan.bt.clone();
        for i in 0..plan.n {
            let s = rat(((i + 2) as i128) * 3, 2);
            for j in 0..plan.r {
                g2[(i, j)] *= s;
            }
            let inv = s.recip();
            for j in 0..plan.n {
                bt2[(i, j)] *= inv;
            }
        }
        let rebal = WinogradPlan { g: g2, bt: bt2, ..plan.clone() };
        let g = pseudorandom_rationals(5, 3);
        let d = pseudorandom_rationals(6, 6);
        assert_eq!(plan.correlate_exact(&g, &d), rebal.correlate_exact(&g, &d));
    }
}
