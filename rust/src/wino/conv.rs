//! Direct convolution/correlation oracles.
//!
//! These are the ground-truth implementations every Winograd path in the
//! crate is validated against (and the "direct" baseline column of the
//! paper's Tables 1–2).

use super::matrix::Mat;

/// Valid 2-D correlation of a single tile: `x` is H×W, `w` is r×r, output
/// is (H−r+1)×(W−r+1). `Y[i,j] = Σ_{a,b} w[a,b] · x[i+a, j+b]`.
pub fn direct_correlate_2d(x: &Mat, w: &Mat) -> Mat {
    let r = w.rows();
    assert_eq!(w.cols(), r);
    assert!(x.rows() >= r && x.cols() >= r);
    let oh = x.rows() - r + 1;
    let ow = x.cols() - r + 1;
    let mut y = Mat::zeros(oh, ow);
    for i in 0..oh {
        for j in 0..ow {
            let mut acc = 0.0;
            for a in 0..r {
                for b in 0..r {
                    acc += w[(a, b)] * x[(i + a, j + b)];
                }
            }
            y[(i, j)] = acc;
        }
    }
    y
}

/// Valid 1-D correlation: `y[t] = Σ_j g[j] d[t+j]`.
pub fn direct_correlate_1d(g: &[f64], d: &[f64]) -> Vec<f64> {
    assert!(d.len() >= g.len());
    let m = d.len() - g.len() + 1;
    (0..m)
        .map(|t| g.iter().enumerate().map(|(j, &gj)| gj * d[t + j]).sum())
        .collect()
}

/// Multi-channel correlation accumulating over channels — oracle for
/// `WinoF::correlate_tile_multichannel` and the NN conv layers.
pub fn direct_correlate_2d_multichannel(xs: &[Mat], ws: &[Mat]) -> Mat {
    assert_eq!(xs.len(), ws.len());
    assert!(!xs.is_empty());
    let mut acc = direct_correlate_2d(&xs[0], &ws[0]);
    for (x, w) in xs.iter().zip(ws).skip(1) {
        let y = direct_correlate_2d(x, w);
        for i in 0..acc.rows() {
            for j in 0..acc.cols() {
                acc[(i, j)] += y[(i, j)];
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlate_1d_known() {
        let y = direct_correlate_1d(&[1.0, 2.0, 3.0], &[1.0, 0.0, -1.0, 2.0]);
        // t=0: 1*1 + 2*0 + 3*(-1) = -2 ; t=1: 1*0 + 2*(-1) + 3*2 = 4
        assert_eq!(y, vec![-2.0, 4.0]);
    }

    #[test]
    fn correlate_2d_identity_kernel() {
        // 1×1 kernel of value 1 returns the input.
        let x = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let w = Mat::from_rows(vec![vec![1.0]]);
        assert_eq!(direct_correlate_2d(&x, &w).data(), x.data());
    }

    #[test]
    fn correlate_2d_known() {
        let x = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        let w = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let y = direct_correlate_2d(&x, &w);
        // y[i,j] = x[i,j] + x[i+1,j+1]
        assert_eq!(y.data(), &[6.0, 8.0, 12.0, 14.0]);
    }

    #[test]
    fn multichannel_accumulates() {
        let x = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let w = Mat::from_rows(vec![vec![2.0]]);
        let y = direct_correlate_2d_multichannel(&[x.clone(), x], &[w.clone(), w]);
        assert_eq!(y.data(), &[4.0, 4.0, 4.0, 4.0]);
    }
}
