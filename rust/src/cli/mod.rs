//! Hand-rolled CLI (clap is not in the vendored crate set): subcommand +
//! `--flag value` parsing, `--help` rendering.
//!
//! Every flag the binary understands is registered in exactly one of two
//! tables — [`VALUE_FLAGS`] (takes a value) or [`SWITCH_FLAGS`] (bare
//! switch). [`Args::parse`] rejects anything not in the tables, and
//! [`help`] renders the flag reference from the same tables, so a flag
//! cannot exist without being documented (and vice versa). Historically
//! unknown `--flags` were treated as switches, which made their intended
//! value silently become a positional argument — a typo like
//! `--max-batch8 8` then changed behaviour without any error.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// One registered flag: name, value metavar (value flags only), help line.
pub struct FlagSpec {
    pub name: &'static str,
    pub metavar: &'static str,
    pub help: &'static str,
}

/// Flags that take a value. The single registry `Args::parse` consumes a
/// value from and `help()` renders the FLAGS section from.
pub const VALUE_FLAGS: &[FlagSpec] = &[
    FlagSpec { name: "--artifact", metavar: "TAG", help: "artifact tag (train/eval/serve)" },
    FlagSpec {
        name: "--artifacts-dir",
        metavar: "DIR",
        help: "artifacts directory (default ./artifacts or $WINOQ_ARTIFACTS)",
    },
    FlagSpec { name: "--config", metavar: "FILE", help: "TOML run config (overrides flags)" },
    FlagSpec { name: "--steps", metavar: "N", help: "training steps" },
    FlagSpec { name: "--lr", metavar: "F", help: "peak learning rate" },
    FlagSpec { name: "--eval-every", metavar: "N", help: "eval every N steps (0 = off)" },
    FlagSpec { name: "--eval-batches", metavar: "N", help: "batches per evaluation" },
    FlagSpec { name: "--checkpoint", metavar: "PATH", help: "checkpoint blob to load/save" },
    FlagSpec { name: "--metrics-csv", metavar: "PATH", help: "write training metrics CSV" },
    FlagSpec {
        name: "--base",
        metavar: "NAME",
        help: "polynomial base: canonical|legendre|chebyshev",
    },
    FlagSpec { name: "--m", metavar: "N", help: "Winograd output tile size m" },
    FlagSpec { name: "--r", metavar: "N", help: "kernel size r" },
    FlagSpec { name: "--bits", metavar: "B", help: "quantization bit width" },
    FlagSpec { name: "--trials", metavar: "N", help: "error-analysis trials" },
    FlagSpec { name: "--table-steps", metavar: "N", help: "per-cell training steps for tables" },
    FlagSpec { name: "--dataset-size", metavar: "N", help: "synthetic dataset size" },
    FlagSpec { name: "--out", metavar: "PATH", help: "output path" },
    // serve flags (see `winoq serve`)
    FlagSpec { name: "--model", metavar: "NAME", help: "serve: registry name for the model" },
    FlagSpec { name: "--requests", metavar: "N", help: "serve: total synthetic requests" },
    FlagSpec { name: "--concurrency", metavar: "K", help: "serve: closed-loop client threads" },
    FlagSpec { name: "--max-batch", metavar: "B", help: "serve: micro-batch size cap" },
    FlagSpec {
        name: "--batch-window-us",
        metavar: "US",
        help: "serve: micro-batch assembly deadline in microseconds",
    },
    FlagSpec { name: "--queue-cap", metavar: "N", help: "serve: admission queue capacity" },
    FlagSpec { name: "--workers", metavar: "W", help: "serve: engine worker threads" },
    FlagSpec {
        name: "--width-mult",
        metavar: "F",
        help: "serve: synthetic ResNet18 width multiplier",
    },
    FlagSpec { name: "--quant", metavar: "CFG", help: "serve: quantization, w8|w8_h9|uN|none" },
    FlagSpec {
        name: "--stats-json",
        metavar: "PATH",
        help: "serve: write the stats report JSON here",
    },
    FlagSpec {
        name: "--bench-json",
        metavar: "PATH",
        help: "serve: also run a max-batch-1 baseline and write a bench JSON",
    },
    FlagSpec {
        name: "--int-bench-json",
        metavar: "PATH",
        help: "serve: time the integer engine vs the dequantize-to-float path (BENCH_int.json)",
    },
    FlagSpec {
        name: "--gemm-json",
        metavar: "PATH",
        help: "bench: time the tiled panel GEMM vs the naive oracles (BENCH_gemm.json)",
    },
    // observability flags (see `winoq serve` / `winoq bench`)
    FlagSpec {
        name: "--trace-json",
        metavar: "PATH",
        help: "serve/soak: write per-request trace events as JSON lines here",
    },
    FlagSpec {
        name: "--metrics-json",
        metavar: "PATH",
        help: "serve: write the metrics-registry snapshot as JSON lines here",
    },
    FlagSpec {
        name: "--health-json",
        metavar: "PATH",
        help: "bench: write the numeric-health saturation report (BENCH_health.json)",
    },
    // tune flags (see `winoq tune`); --plan is shared with `winoq serve`
    FlagSpec {
        name: "--plan",
        metavar: "PATH",
        help: "serve: load a tuned NetPlan JSON (from `winoq tune`)",
    },
    FlagSpec {
        name: "--plan-out",
        metavar: "PATH",
        help: "tune: write the NetPlan artifact here (default netplan.json)",
    },
    FlagSpec {
        name: "--objective",
        metavar: "NAME",
        help: "tune: selection objective, error|throughput|balanced",
    },
    FlagSpec {
        name: "--max-err",
        metavar: "E",
        help: "tune: absolute per-layer error budget (default: uniform baseline's)",
    },
    FlagSpec {
        name: "--calib-pct",
        metavar: "P",
        help: "tune: activation calibration percentile (default 100 = max)",
    },
    FlagSpec {
        name: "--calib-batch",
        metavar: "N",
        help: "tune: calibration batch size (default 4)",
    },
    FlagSpec {
        name: "--grid",
        metavar: "NAME",
        help: "tune: candidate grid, full|tiny",
    },
    FlagSpec {
        name: "--layers",
        metavar: "N",
        help: "tune: tune only the first N eligible layers (0 = all)",
    },
    // drift flags (see `winoq serve` / ARCHITECTURE.md "Accuracy drift")
    FlagSpec {
        name: "--drift-json",
        metavar: "PATH",
        help: "serve: enable shadow-oracle drift monitoring, write its report here",
    },
    FlagSpec {
        name: "--drift-stride",
        metavar: "N",
        help: "serve/soak: shadow-sample every Nth request span (default 16; 0 = off)",
    },
    FlagSpec {
        name: "--input-scale",
        metavar: "F",
        help: "serve: scale synthetic inputs by F (out-of-distribution drift exercise)",
    },
    FlagSpec {
        name: "--drift-scale",
        metavar: "F",
        help: "soak: scale the synthetic drift error by F (models OOD traffic)",
    },
    // benchdiff flags (see `winoq benchdiff`)
    FlagSpec {
        name: "--baseline",
        metavar: "DIR",
        help: "benchdiff: directory of committed baseline BENCH_*.json artifacts",
    },
    FlagSpec {
        name: "--current",
        metavar: "DIR",
        help: "benchdiff: directory holding the current run's BENCH_*.json artifacts",
    },
    // soak flags (see `winoq serve --soak`)
    FlagSpec {
        name: "--models",
        metavar: "N",
        help: "soak: simulated model shards (default 2)",
    },
    FlagSpec {
        name: "--deadline-us",
        metavar: "US",
        help: "soak: base relative request deadline in microseconds",
    },
    FlagSpec {
        name: "--soak-json",
        metavar: "PATH",
        help: "soak: write the soak report JSON here (default BENCH_serve_soak.json)",
    },
    FlagSpec { name: "--seed", metavar: "S", help: "soak: PRNG seed for the request trace" },
    FlagSpec {
        name: "--chaos-seed",
        metavar: "S",
        help: "serve/soak: offset for every --chaos-* modular schedule (default 0)",
    },
    FlagSpec {
        name: "--chaos-panic-every",
        metavar: "N",
        help: "serve/soak: panic the worker on every Nth batch (0 = off)",
    },
    FlagSpec {
        name: "--chaos-corrupt-every",
        metavar: "N",
        help: "serve/soak: corrupt batch activations on every Nth batch (0 = off)",
    },
    FlagSpec {
        name: "--chaos-corrupt-scale",
        metavar: "F",
        help: "serve/soak: activation multiplier for corrupt faults (default 100)",
    },
    FlagSpec {
        name: "--chaos-latency-every",
        metavar: "N",
        help: "serve/soak: inject latency on every Nth batch (0 = off)",
    },
    FlagSpec {
        name: "--chaos-latency-us",
        metavar: "US",
        help: "serve/soak: injected delay per latency fault (default 1000 µs)",
    },
    FlagSpec {
        name: "--chaos-burst-every",
        metavar: "N",
        help: "soak: compress arrival gaps every Nth arrival window (0 = off)",
    },
    FlagSpec {
        name: "--chaos-burst-len",
        metavar: "K",
        help: "soak: consecutive arrivals each saturation burst compresses (default 8)",
    },
    FlagSpec {
        name: "--fallback-alerts",
        metavar: "N",
        help: "serve: consecutive drift violations that degrade a layer one rung (default 2)",
    },
    FlagSpec {
        name: "--fallback-quiet",
        metavar: "N",
        help: "serve: consecutive in-budget samples that restore a degraded layer (default 16)",
    },
];

/// Bare switches (no value).
pub const SWITCH_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "--synthetic",
        metavar: "",
        help: "serve: run the built-in closed-loop client",
    },
    FlagSpec {
        name: "--soak",
        metavar: "",
        help: "serve: run the deterministic multi-model soak simulation",
    },
    FlagSpec {
        name: "--no-simd",
        metavar: "",
        help: "force the scalar GEMM micro-kernels (skip AVX2/NEON detection; \
               also WINOQ_NO_SIMD=1)",
    },
    FlagSpec { name: "--verbose", metavar: "", help: "more logging where supported" },
    FlagSpec { name: "--help", metavar: "", help: "show this help (also -h)" },
];

fn value_flag(name: &str) -> bool {
    VALUE_FLAGS.iter().any(|f| f.name == name)
}

fn switch_flag(name: &str) -> bool {
    SWITCH_FLAGS.iter().any(|f| f.name == name)
}

/// Parsed command line: subcommand, positional args, `--key value` flags
/// and bare `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if a == "-h" {
                // The short help idiom must never be an unknown-flag error.
                args.switches.push("--help".to_string());
            } else if a.starts_with("--") {
                if value_flag(a) {
                    let Some(v) = it.next() else {
                        bail!("flag {a} requires a value");
                    };
                    args.flags.insert(a.clone(), v.clone());
                } else if switch_flag(a) {
                    args.switches.push(a.clone());
                } else {
                    bail!("unknown flag {a} (run `winoq help` for the flag reference)");
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{name} = {v:?} is not an integer")),
        }
    }

    pub fn flag_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{name} = {v:?} is not a number")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{name} = {v:?} is not a number")),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

const COMMANDS: &str = "\
winoq — quantized Winograd/Toom-Cook convolution beyond the canonical base

USAGE: winoq <command> [flags]

COMMANDS:
  train           train one artifact
                    --artifact <tag> [--steps N] [--lr F] [--eval-every N]
                    [--checkpoint PATH] [--metrics-csv PATH]
                    [--config FILE]   (TOML config overrides flags)
  eval            evaluate a checkpoint
                    --artifact <tag> [--checkpoint PATH] [--eval-batches N]
  tables          regenerate the paper's Tables 1 & 2
                    [--table-steps N] (per-cell training steps, default 150)
  list            list available artifacts
  gen-matrices    print exact G / Bᵀ / Aᵀ / P matrices
                    [--m 4] [--r 3] [--base legendre]
  error-analysis  numerical-error sweep across tile sizes and bases
                    [--trials N] [--bits B]
  serve           micro-batching inference server (pure rust engine path)
                    --synthetic [--requests N] [--concurrency K]
                    [--max-batch B] [--batch-window-us US] [--queue-cap N]
                    [--workers W] [--width-mult F] [--m 4] [--base legendre]
                    [--quant w8|w8_h9|none] [--artifact TAG] [--checkpoint P]
                    [--plan NETPLAN.json] [--stats-json PATH] [--bench-json PATH]
                    [--int-bench-json PATH] [--trace-json PATH]
                    [--metrics-json PATH] [--drift-json PATH] [--drift-stride N]
                    [--input-scale F] [--chaos-* ...] [--fallback-alerts N]
                    [--fallback-quiet N]
                  deterministic multi-model stress/soak simulation
                    --soak [--requests N] [--models N] [--deadline-us US]
                    [--seed S] [--queue-cap N] [--max-batch B]
                    [--batch-window-us US] [--workers W] [--soak-json PATH]
                    [--trace-json PATH] [--drift-stride N] [--drift-scale F]
                    [--chaos-seed S] [--chaos-panic-every N]
                    [--chaos-corrupt-every N] [--chaos-corrupt-scale F]
                    [--chaos-latency-every N] [--chaos-latency-us US]
                    [--chaos-burst-every N] [--chaos-burst-len K]
  tune            per-layer base/tile/bit-width autotuner → NetPlan JSON
                    --synthetic [--grid full|tiny] [--layers N]
                    [--objective error|throughput|balanced] [--max-err E]
                    [--calib-pct P] [--calib-batch N] [--width-mult F]
                    [--plan-out netplan.json] [--out BENCH_tune.json]
  bench           in-binary micro-benchmarks (no cargo-bench recompile)
                    --gemm-json BENCH_gemm.json [--m 4]
                    (tiled panel GEMM vs naive oracles, float + int)
                    --health-json BENCH_health.json
                    (numeric-health saturation counters on adversarial input)
  benchdiff       gate the current BENCH_*.json artifacts against baselines
                    --baseline bench/baselines --current .
                    [--out BENCH_diff.json]   (exit 1 on any regression)
  help            this message
";

/// Render the full help text: the command summary plus a flag reference
/// generated from [`VALUE_FLAGS`] / [`SWITCH_FLAGS`] — the same tables the
/// parser accepts, so help and behaviour cannot drift apart.
pub fn help() -> String {
    let mut out = String::from(COMMANDS);
    out.push_str("\nFLAGS:\n");
    for f in VALUE_FLAGS {
        let head = format!("{} <{}>", f.name, f.metavar);
        out.push_str(&format!("  {head:<26} {}\n", f.help));
    }
    for f in SWITCH_FLAGS {
        out.push_str(&format!("  {:<26} {}\n", f.name, f.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(&sv(&[
            "train",
            "--artifact",
            "t2-direct-8b-w0.25",
            "--steps",
            "100",
            "--verbose",
            "pos1",
        ]))
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("--artifact"), Some("t2-direct-8b-w0.25"));
        assert_eq!(a.flag_u64("--steps", 0).unwrap(), 100);
        assert!(a.has_switch("--verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["train", "--steps"])).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        // The historical bug: `--max-bach 8` (typo) used to parse as a
        // switch plus positional "8" — it must be a hard error instead.
        let err = Args::parse(&sv(&["serve", "--max-bach", "8"])).unwrap_err();
        assert!(err.to_string().contains("--max-bach"), "{err}");
    }

    #[test]
    fn help_idioms_parse_as_help_switch() {
        // `winoq serve --help` and `winoq serve -h` must reach the help
        // path, not die as unknown flags/positionals.
        for idiom in ["--help", "-h"] {
            let a = Args::parse(&sv(&["serve", idiom])).unwrap();
            assert!(a.has_switch("--help"), "{idiom} must set the help switch");
            assert!(a.positional.is_empty());
        }
    }

    #[test]
    fn serve_flags_registered() {
        let a = Args::parse(&sv(&[
            "serve",
            "--synthetic",
            "--requests",
            "64",
            "--max-batch",
            "8",
            "--batch-window-us",
            "500",
        ]))
        .unwrap();
        assert!(a.has_switch("--synthetic"));
        assert_eq!(a.flag_u64("--requests", 0).unwrap(), 64);
        assert_eq!(a.flag_u64("--max-batch", 0).unwrap(), 8);
        assert_eq!(a.flag_u64("--batch-window-us", 0).unwrap(), 500);
    }

    #[test]
    fn tune_flags_registered() {
        let a = Args::parse(&sv(&[
            "tune",
            "--synthetic",
            "--grid",
            "tiny",
            "--layers",
            "2",
            "--objective",
            "balanced",
            "--max-err",
            "0.05",
            "--calib-pct",
            "99.5",
            "--plan-out",
            "np.json",
        ]))
        .unwrap();
        assert!(a.has_switch("--synthetic"));
        assert_eq!(a.flag("--grid"), Some("tiny"));
        assert_eq!(a.flag_u64("--layers", 0).unwrap(), 2);
        assert_eq!(a.flag("--objective"), Some("balanced"));
        assert!((a.flag_f64("--max-err", 0.0).unwrap() - 0.05).abs() < 1e-12);
        assert!((a.flag_f64("--calib-pct", 100.0).unwrap() - 99.5).abs() < 1e-12);
        assert!(a.flag_f64("--max-err", 0.0).is_ok());
        assert!(Args::parse(&sv(&["tune", "--max-err", "abc"]))
            .unwrap()
            .flag_f64("--max-err", 0.0)
            .is_err());
        assert_eq!(a.flag("--plan-out"), Some("np.json"));
    }

    #[test]
    fn serve_plan_flag_registered() {
        let a = Args::parse(&sv(&["serve", "--synthetic", "--plan", "netplan.json"])).unwrap();
        assert_eq!(a.flag("--plan"), Some("netplan.json"));
    }

    #[test]
    fn bench_gemm_json_flag_registered() {
        // The bench subcommand's flag lives in VALUE_FLAGS like every
        // other flag: it takes a value, is rendered by help(), and a
        // typo'd variant is a hard error.
        let a = Args::parse(&sv(&["bench", "--gemm-json", "BENCH_gemm.json"])).unwrap();
        assert_eq!(a.flag("--gemm-json"), Some("BENCH_gemm.json"));
        assert!(Args::parse(&sv(&["bench", "--gemm-json"])).is_err(), "value required");
        assert!(Args::parse(&sv(&["bench", "--gem-json", "x"])).is_err(), "typo rejected");
        assert!(help().contains("--gemm-json"));
        assert!(help().contains("bench "), "help must document the bench command");
    }

    #[test]
    fn observability_flags_registered() {
        let a = Args::parse(&sv(&[
            "serve",
            "--synthetic",
            "--trace-json",
            "trace.jsonl",
            "--metrics-json",
            "metrics.jsonl",
        ]))
        .unwrap();
        assert_eq!(a.flag("--trace-json"), Some("trace.jsonl"));
        assert_eq!(a.flag("--metrics-json"), Some("metrics.jsonl"));
        let b = Args::parse(&sv(&["bench", "--health-json", "BENCH_health.json"])).unwrap();
        assert_eq!(b.flag("--health-json"), Some("BENCH_health.json"));
        assert!(Args::parse(&sv(&["serve", "--trace-json"])).is_err(), "value required");
        for f in ["--trace-json", "--metrics-json", "--health-json"] {
            assert!(help().contains(f), "help must document {f}");
        }
    }

    #[test]
    fn drift_and_benchdiff_flags_registered() {
        let a = Args::parse(&sv(&[
            "serve",
            "--synthetic",
            "--drift-json",
            "drift.json",
            "--drift-stride",
            "8",
            "--input-scale",
            "100",
        ]))
        .unwrap();
        assert_eq!(a.flag("--drift-json"), Some("drift.json"));
        assert_eq!(a.flag_u64("--drift-stride", 16).unwrap(), 8);
        assert!((a.flag_f64("--input-scale", 1.0).unwrap() - 100.0).abs() < 1e-12);
        let b = Args::parse(&sv(&[
            "benchdiff",
            "--baseline",
            "bench/baselines",
            "--current",
            ".",
        ]))
        .unwrap();
        assert_eq!(b.command, "benchdiff");
        assert_eq!(b.flag("--baseline"), Some("bench/baselines"));
        assert_eq!(b.flag("--current"), Some("."));
        assert!(Args::parse(&sv(&["serve", "--drift-json"])).is_err(), "value required");
        for f in ["--drift-json", "--drift-stride", "--input-scale", "--baseline", "--current"] {
            assert!(help().contains(f), "help must document {f}");
        }
        assert!(help().contains("benchdiff"), "help must document the benchdiff command");
    }

    #[test]
    fn chaos_and_fallback_flags_registered() {
        // The whole fault-injection family parses, round-trips its
        // values, and is documented by help() — a typo'd chaos flag is
        // a hard parse error, never a silently-ignored switch.
        let a = Args::parse(&sv(&[
            "serve",
            "--synthetic",
            "--chaos-seed",
            "7",
            "--chaos-panic-every",
            "17",
            "--chaos-latency-every",
            "5",
            "--chaos-latency-us",
            "2000",
            "--chaos-corrupt-every",
            "3",
            "--chaos-corrupt-scale",
            "50",
            "--chaos-burst-every",
            "40",
            "--chaos-burst-len",
            "12",
            "--fallback-alerts",
            "1",
            "--fallback-quiet",
            "4",
        ]))
        .unwrap();
        assert_eq!(a.flag_u64("--chaos-seed", 0).unwrap(), 7);
        assert_eq!(a.flag_u64("--chaos-panic-every", 0).unwrap(), 17);
        assert_eq!(a.flag_u64("--chaos-latency-every", 0).unwrap(), 5);
        assert_eq!(a.flag_u64("--chaos-latency-us", 1000).unwrap(), 2000);
        assert_eq!(a.flag_u64("--chaos-corrupt-every", 0).unwrap(), 3);
        assert!((a.flag_f64("--chaos-corrupt-scale", 100.0).unwrap() - 50.0).abs() < 1e-12);
        assert_eq!(a.flag_u64("--chaos-burst-every", 0).unwrap(), 40);
        assert_eq!(a.flag_u64("--chaos-burst-len", 8).unwrap(), 12);
        assert_eq!(a.flag_u64("--fallback-alerts", 2).unwrap(), 1);
        assert_eq!(a.flag_u64("--fallback-quiet", 16).unwrap(), 4);
        assert!(Args::parse(&sv(&["serve", "--chaos-panic-every"])).is_err(), "value required");
        assert!(Args::parse(&sv(&["serve", "--chaos-panics-every", "17"])).is_err(), "typo");
        for f in ["--chaos-panic-every", "--chaos-burst-len", "--fallback-quiet"] {
            assert!(help().contains(f), "help must document {f}");
        }
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["eval"])).unwrap();
        assert_eq!(a.flag_or("--artifact", "x"), "x");
        assert_eq!(a.flag_u64("--steps", 7).unwrap(), 7);
        assert!((a.flag_f32("--lr", 0.5).unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bad_number() {
        let a = Args::parse(&sv(&["train", "--steps", "abc"])).unwrap();
        assert!(a.flag_u64("--steps", 0).is_err());
    }

    #[test]
    fn help_lists_every_registered_flag() {
        let h = help();
        for f in VALUE_FLAGS.iter().chain(SWITCH_FLAGS) {
            assert!(h.contains(f.name), "help() is missing {}", f.name);
        }
        // The retired serve-demo command must not resurface.
        assert!(!h.contains("serve-demo"));
    }
}
