//! Hand-rolled CLI (clap is not in the vendored crate set): subcommand +
//! `--flag value` parsing, `--help` rendering.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, `--key value` flags
/// and bare `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

/// Flags that take a value (everything else starting with `--` is a switch).
const VALUE_FLAGS: &[&str] = &[
    "--artifact",
    "--artifacts-dir",
    "--config",
    "--steps",
    "--lr",
    "--eval-every",
    "--eval-batches",
    "--checkpoint",
    "--metrics-csv",
    "--base",
    "--m",
    "--r",
    "--bits",
    "--trials",
    "--table-steps",
    "--dataset-size",
    "--out",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(_name) = a.strip_prefix("--") {
                if VALUE_FLAGS.contains(&a.as_str()) {
                    let Some(v) = it.next() else {
                        bail!("flag {a} requires a value");
                    };
                    args.flags.insert(a.clone(), v.clone());
                } else {
                    args.switches.push(a.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{name} = {v:?} is not an integer")),
        }
    }

    pub fn flag_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{name} = {v:?} is not a number")),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

pub const HELP: &str = "\
winoq — quantized Winograd/Toom-Cook convolution beyond the canonical base

USAGE: winoq <command> [flags]

COMMANDS:
  train           train one artifact
                    --artifact <tag> [--steps N] [--lr F] [--eval-every N]
                    [--checkpoint PATH] [--metrics-csv PATH]
                    [--config FILE]   (TOML config overrides flags)
  eval            evaluate a checkpoint
                    --artifact <tag> [--checkpoint PATH] [--eval-batches N]
  tables          regenerate the paper's Tables 1 & 2
                    [--table-steps N] (per-cell training steps, default 150)
  list            list available artifacts
  gen-matrices    print exact G / Bᵀ / Aᵀ / P matrices
                    [--m 4] [--r 3] [--base legendre]
  error-analysis  numerical-error sweep across tile sizes and bases
                    [--trials N] [--bits B]
  serve-demo      quantized int8 winograd inference demo (pure rust)
  help            this message

Common flags: --artifacts-dir DIR (default ./artifacts, or $WINOQ_ARTIFACTS)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(&sv(&[
            "train",
            "--artifact",
            "t2-direct-8b-w0.25",
            "--steps",
            "100",
            "--verbose",
            "pos1",
        ]))
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("--artifact"), Some("t2-direct-8b-w0.25"));
        assert_eq!(a.flag_u64("--steps", 0).unwrap(), 100);
        assert!(a.has_switch("--verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["train", "--steps"])).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["eval"])).unwrap();
        assert_eq!(a.flag_or("--artifact", "x"), "x");
        assert_eq!(a.flag_u64("--steps", 7).unwrap(), 7);
        assert!((a.flag_f32("--lr", 0.5).unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bad_number() {
        let a = Args::parse(&sv(&["t", "--steps", "abc"])).unwrap();
        assert!(a.flag_u64("--steps", 0).is_err());
    }
}
