//! Coordinator integration: short end-to-end training runs through the
//! full L3 loop (loader → PJRT step → schedule → eval → checkpoint), and
//! config-file-driven runs.

use std::path::{Path, PathBuf};
use winoq::config::{Config, RunConfig};
use winoq::coordinator::schedule::Schedule;
use winoq::coordinator::trainer::{self, TrainCfg};
use winoq::runtime::Artifact;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const TAG: &str = "t2-direct-8b-w0.25";

fn have_artifacts() -> bool {
    artifacts().join(format!("{TAG}.manifest.txt")).exists()
}

#[test]
fn short_training_run_improves_over_init() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts();
    let artifact = Artifact::load(&dir, TAG).unwrap();
    // Accuracy at init.
    let init_state = artifact.init_state(&dir).unwrap();
    let (_, acc0) = trainer::evaluate(&artifact, &init_state, 2).unwrap();

    let tmp = std::env::temp_dir().join("winoq_test_ckpt.bin");
    let cfg = TrainCfg {
        steps: 30,
        schedule: Schedule::WarmupCosine { lr: 0.08, warmup: 3, total: 30, final_frac: 0.1 },
        eval_every: 15,
        eval_batches: 2,
        log_every: 0,
        checkpoint: Some(tmp.clone()),
        dataset_size: 512,
    };
    let outcome = trainer::train(&artifact, &dir, &cfg).unwrap();
    // 30 steps on the easy synthetic task must beat the untrained net.
    assert!(
        outcome.final_eval_acc > acc0 + 0.05,
        "training did not improve: {acc0} -> {}",
        outcome.final_eval_acc
    );
    // Metrics were recorded for every step plus periodic evals.
    assert_eq!(outcome.log.records.len(), 30);
    assert!(outcome.log.evals.len() >= 2);
    // Loss curve went down on average.
    let early = outcome.log.records[..5].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    let late = outcome.log.records[25..].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    assert!(late < early, "loss did not descend: {early} -> {late}");

    // Checkpoint exists, reloads, and evaluates to the same accuracy.
    let bytes = std::fs::read(&tmp).unwrap();
    let restored = artifact.state_from_bytes(&bytes).unwrap();
    let (_, acc_restored) = trainer::evaluate(&artifact, &restored, 2).unwrap();
    assert!((acc_restored - outcome.final_eval_acc).abs() < 1e-9);
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn config_driven_run_parses_and_trains() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let toml = format!(
        "[run]\nartifact = {TAG}\nartifacts_dir = {}\n\n\
         [train]\nsteps = 5\nlog_every = 0\n\n\
         [schedule]\nkind = constant\nlr = 0.05\n",
        artifacts().display()
    );
    let cfg = Config::parse(&toml).unwrap();
    let run = RunConfig::from_config(&cfg).unwrap();
    assert_eq!(run.train.steps, 5);
    let artifact = Artifact::load(&run.artifacts_dir, &run.tag).unwrap();
    let outcome = trainer::train(&artifact, &run.artifacts_dir, &run.train).unwrap();
    assert_eq!(outcome.log.records.len(), 5);
    assert!(outcome.log.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn deterministic_given_same_seed_and_steps() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts();
    let artifact = Artifact::load(&dir, TAG).unwrap();
    let cfg = TrainCfg {
        steps: 3,
        schedule: Schedule::Constant { lr: 0.05 },
        eval_every: 0,
        eval_batches: 1,
        log_every: 0,
        checkpoint: None,
        dataset_size: 256,
    };
    let a = trainer::train(&artifact, &dir, &cfg).unwrap();
    let b = trainer::train(&artifact, &dir, &cfg).unwrap();
    // Same data order (deterministic loader) + same init ⇒ identical loss.
    for (ra, rb) in a.log.records.iter().zip(&b.log.records) {
        assert_eq!(ra.loss, rb.loss, "nondeterministic step {}", ra.step);
    }
    assert_eq!(a.final_eval_acc, b.final_eval_acc);
}
