//! Engine-vs-oracle parity (ISSUE 1 acceptance): the batched flat-buffer
//! [`WinoEngine`] must match
//!
//! * the f64 direct-convolution oracle
//!   (`wino::conv::direct_correlate_2d_multichannel` semantics, computed
//!   here over the full NCHW shape) within 1e-9 in float mode, and
//! * the per-tile `WinoConv2d::forward_reference` path **bit-for-bit**
//!   in float mode and within the final-stage quantization step in the
//!   8-bit path (in practice also bit-for-bit, which is what we assert),
//!
//! across a property-style sweep of shapes: odd output sizes (edge-tile
//! clamping), C≠K, batch>1, every polynomial base, F(2,3)/F(4,3), and
//! both quantization operating points.

use winoq::engine::{EngineScratch, WinoEngine};
use winoq::nn::layers::Conv2dCfg;
use winoq::nn::tensor::Tensor;
use winoq::nn::winolayer::WinoConv2d;
use winoq::quant::QuantConfig;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

fn rand_tensor(seed: u64, dims: &[usize], scale: f64) -> Tensor {
    let mut rng = Prng::new(seed);
    let n = dims.iter().product();
    Tensor::from_vec(dims, (0..n).map(|_| rng.uniform(scale) as f32).collect())
}

/// f64 direct convolution over the f64-widened f32 inputs — the oracle the
/// engine's internal precision is measured against.
fn direct_f64(x: &Tensor, w: &Tensor, padding: usize) -> (Vec<f64>, [usize; 4]) {
    let (bn, c, h, wd) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (k, _, r, _) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    let oh = h + 2 * padding - r + 1;
    let ow = wd + 2 * padding - r + 1;
    let mut y = vec![0.0f64; bn * k * oh * ow];
    for ni in 0..bn {
        for ki in 0..k {
            for oi in 0..oh {
                for oj in 0..ow {
                    let mut acc = 0.0f64;
                    for ci in 0..c {
                        for a in 0..r {
                            let ih = (oi + a) as isize - padding as isize;
                            if ih < 0 || ih >= h as isize {
                                continue;
                            }
                            for b in 0..r {
                                let iw = (oj + b) as isize - padding as isize;
                                if iw < 0 || iw >= wd as isize {
                                    continue;
                                }
                                acc += x.at4(ni, ci, ih as usize, iw as usize) as f64
                                    * w.at4(ki, ci, a, b) as f64;
                            }
                        }
                    }
                    y[((ni * k + ki) * oh + oi) * ow + oj] = acc;
                }
            }
        }
    }
    (y, [bn, k, oh, ow])
}

/// Property sweep: (m, dims of x, dims of w, padding).
fn shape_sweep() -> Vec<(usize, Vec<usize>, Vec<usize>, usize)> {
    vec![
        // Exact tile multiples.
        (4, vec![1, 1, 6, 6], vec![1, 1, 3, 3], 0),
        (4, vec![2, 3, 10, 10], vec![4, 3, 3, 3], 0),
        // Edge clamping: 7×7 and 9×9 outputs are not multiples of m=4.
        (4, vec![1, 2, 9, 9], vec![2, 2, 3, 3], 0),
        (4, vec![3, 5, 9, 9], vec![2, 5, 3, 3], 1),
        // Same-padding square, C≠K, batch > 1.
        (4, vec![2, 4, 8, 8], vec![7, 4, 3, 3], 1),
        // F(2,3) variant.
        (2, vec![1, 3, 8, 8], vec![2, 3, 3, 3], 1),
        (2, vec![2, 2, 7, 7], vec![3, 2, 3, 3], 0),
    ]
}

#[test]
fn engine_f64_matches_direct_oracle_within_1e9() {
    for (si, (m, xd, wd, pad)) in shape_sweep().into_iter().enumerate() {
        let x = rand_tensor(100 + si as u64, &xd, 1.0);
        let w = rand_tensor(200 + si as u64, &wd, 0.5);
        let (oracle, odims) = direct_f64(&x, &w, pad);
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let engine = WinoEngine::from_weights(m, &w, base);
            let (got, gdims) = engine.forward_f64(&x, Conv2dCfg { stride: 1, padding: pad });
            assert_eq!(gdims, odims, "shape {si} dims mismatch");
            let mut max_err = 0.0f64;
            for (a, b) in got.iter().zip(&oracle) {
                max_err = max_err.max((a - b).abs());
            }
            assert!(
                max_err < 1e-9,
                "shape {si} {base:?}: engine-vs-oracle max|err| = {max_err:e}"
            );
        }
    }
}

#[test]
fn engine_matches_per_tile_reference_bit_for_bit_float() {
    for (si, (m, xd, wd, pad)) in shape_sweep().into_iter().enumerate() {
        let x = rand_tensor(300 + si as u64, &xd, 1.0);
        let w = rand_tensor(400 + si as u64, &wd, 0.5);
        let cfg = Conv2dCfg { stride: 1, padding: pad };
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let layer = WinoConv2d::new(m, &w, base);
            let reference = layer.forward_reference(&x, cfg);
            let batched = layer.forward(&x, cfg);
            assert_eq!(reference.dims, batched.dims);
            for (i, (a, b)) in reference.data.iter().zip(&batched.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shape {si} {base:?} idx {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn engine_matches_per_tile_reference_in_8bit_path() {
    // Quantized mode, **float fake-quant engine** vs the per-tile
    // reference: the engine replays the per-tile cast sites exactly, so
    // the two paths agree bit-for-bit — assert the stronger property and
    // separately sanity-check the tolerance bound. (The serving dispatch
    // `forward` runs the integer engine for quantized layers — a
    // different numeric route pinned against its own scalar oracle in
    // `rust/tests/int_parity.rs`.)
    for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
        for (si, (m, xd, wd, pad)) in shape_sweep().into_iter().enumerate() {
            let x = rand_tensor(500 + si as u64, &xd, 1.0);
            let w = rand_tensor(600 + si as u64, &wd, 0.3);
            let cfg = Conv2dCfg { stride: 1, padding: pad };
            let mut layer = WinoConv2d::new(m, &w, Base::Legendre);
            layer.quantize(qcfg, &x, pad);
            let reference = layer.forward_reference(&x, cfg);
            let batched = layer.forward_float(&x, cfg);
            let out_step = layer
                .quant
                .as_ref()
                .map(|(_, s)| s.output.scale as f32)
                .unwrap();
            for (i, (a, b)) in reference.data.iter().zip(&batched.data).enumerate() {
                assert!(
                    (a - b).abs() <= out_step + 1e-9,
                    "shape {si} idx {i}: {a} vs {b} beyond one output step"
                );
                assert_eq!(a.to_bits(), b.to_bits(), "shape {si} idx {i}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn scratch_reuse_across_heterogeneous_shapes() {
    // One workspace threaded through different layer shapes (the ResNet
    // serving pattern) must not change any result.
    let mut scratch = EngineScratch::new();
    for (si, (m, xd, wd, pad)) in shape_sweep().into_iter().enumerate() {
        let x = rand_tensor(700 + si as u64, &xd, 1.0);
        let w = rand_tensor(800 + si as u64, &wd, 0.5);
        let cfg = Conv2dCfg { stride: 1, padding: pad };
        let layer = WinoConv2d::new(m, &w, Base::Legendre);
        let fresh = layer.forward(&x, cfg);
        let reused = layer.forward_with_scratch(&x, cfg, &mut scratch);
        assert_eq!(fresh.data, reused.data, "shape {si}: scratch reuse diverged");
    }
}
