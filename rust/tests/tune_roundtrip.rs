//! Tune-subsystem integration pins:
//!
//! 1. the exact-arithmetic property behind every tuner candidate — the
//!    base-change pair satisfies `P·P⁻¹ = P⁻¹·P = I` *exactly* (over
//!    rationals) for every base × transform size the grid sweeps;
//! 2. the tune → serve round trip — a NetPlan serialized to JSON,
//!    reloaded, and registered through the serving registry (plan cache,
//!    weight banks, `from_transformed` lowering) produces per-layer
//!    forwards **bit-identical** to engines built directly from the same
//!    per-layer parameters (`tune::build_plan_net`'s cache-free lowering).

use winoq::nn::layers::Conv2dCfg;
use winoq::nn::{ResNet18, ResNetCfg};
use winoq::quant::QuantConfig;
use winoq::serve::ModelRegistry;
use winoq::tune::netplan::{LayerPlan, NetPlan, NETPLAN_VERSION};
use winoq::tune::{build_plan_net, default_grid};
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::matrix::RatMat;

#[test]
fn base_change_inverse_is_exact_for_every_grid_candidate() {
    // Every (base, n = m + 2) pair the tuner can put in a NetPlan must
    // have an exactly-invertible base change — the algebraic cancellation
    // the paper's eq. 4 relies on. Checked over rationals, not floats.
    for cand in default_grid() {
        let n = cand.n();
        let bc = BaseChange::new(cand.base, n);
        let id = RatMat::identity(n);
        assert_eq!(
            bc.p.matmul(&bc.p_inv),
            id,
            "P·P⁻¹ ≠ I for {} n={n}",
            cand.base.name()
        );
        assert_eq!(
            bc.p_inv.matmul(&bc.p),
            id,
            "P⁻¹·P ≠ I for {} n={n}",
            cand.base.name()
        );
    }
}

fn heterogeneous_plan() -> NetPlan {
    NetPlan {
        version: NETPLAN_VERSION,
        model: "resnet18-synthetic".into(),
        width_mult: 0.25,
        num_classes: 10,
        image_hw: 32,
        seed: 11,
        calib_batch: 2,
        // Off-max percentile so the round trip also pins the
        // percentile-calibration path.
        calib_pct: 99.0,
        layers: vec![
            LayerPlan {
                layer: "stem".into(),
                m: 4,
                base: Base::Legendre,
                quant: QuantConfig::w8_h9(),
                tuned_err: Some(0.005),
                tuned_tiles_per_sec: Some(500000.0),
            },
            LayerPlan {
                layer: "s0b0.conv1".into(),
                m: 2,
                base: Base::Canonical,
                quant: QuantConfig::w8(),
                tuned_err: None,
                tuned_tiles_per_sec: None,
            },
            LayerPlan {
                layer: "s0b1.conv2".into(),
                m: 6,
                base: Base::Chebyshev,
                quant: QuantConfig::w8_h9(),
                tuned_err: Some(0.0075),
                tuned_tiles_per_sec: Some(250000.0),
            },
        ],
    }
}

#[test]
fn netplan_serve_round_trip_is_bit_identical() {
    let plan = heterogeneous_plan();

    // Serialize → disk → reload: lossless.
    let dir = std::env::temp_dir().join(format!("winoq-tune-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("netplan.json");
    plan.save(&path).unwrap();
    let loaded = NetPlan::load(&path).unwrap();
    assert_eq!(loaded, plan, "NetPlan JSON round trip must be lossless");
    std::fs::remove_dir_all(&dir).ok();

    // Serve side: registry builds the heterogeneous net through its plan
    // cache and transformed-weight banks.
    let mut registry = ModelRegistry::new();
    let served = registry.register_netplan("tuned", &loaded).unwrap();
    assert_eq!(registry.plans().plan_count(), 3, "three distinct (m, base) keys");

    // Direct side: the cache-free lowering from the same per-layer
    // params (WinoConv2d::with_plan + per-layer calibration).
    let cfg = ResNetCfg {
        width_mult: plan.width_mult,
        num_classes: plan.num_classes,
        mode: winoq::nn::ConvMode::Direct, // init_params ignores the mode
    };
    let params = ResNet18::init_params(&cfg, plan.seed);
    let direct_net = build_plan_net(&plan, &params).unwrap();

    // Whole-net logits: bit-identical.
    let (eval_x, _) = winoq::data::synthcifar::generate_batch(
        winoq::data::synthcifar::TEST_SEED,
        0,
        4,
    );
    let served_logits = served.net.forward(&eval_x);
    let direct_logits = direct_net.forward(&eval_x);
    assert_eq!(served_logits.dims, direct_logits.dims);
    for (a, b) in served_logits.data.iter().zip(&direct_logits.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "served ≠ directly-built logits");
    }

    // Per-layer forwards on each layer's real activations: bit-identical,
    // and each layer carries exactly the plan's operating point.
    let captured = direct_net.capture_wino_inputs(&eval_x);
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    for l in &plan.layers {
        let x = &captured[&l.layer];
        let a = served.net.wino_layer(&l.layer).unwrap();
        let b = direct_net.wino_layer(&l.layer).unwrap();
        assert_eq!(a.wf.m, l.m);
        assert_eq!(a.wf.base, l.base);
        assert_eq!(a.quant.unwrap().0, l.quant);
        assert_eq!(b.quant.unwrap().0, l.quant);
        let ya = a.forward(x, conv);
        let yb = b.forward(x, conv);
        assert_eq!(ya.dims, yb.dims);
        for (va, vb) in ya.data.iter().zip(&yb.data) {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "layer {} diverges between serve and direct lowering",
                l.layer
            );
        }
    }
    // Unplanned layers stayed direct on both sides.
    assert!(served.net.wino_layer("s0b0.conv2").is_none());
    assert!(direct_net.wino_layer("s0b0.conv2").is_none());
}
