//! Serve-queue stress (ISSUE 4): many submitter threads racing workers,
//! shape rejection, `close` and `abort` must terminate with **every
//! request accounted for** — each submission attempt ends in exactly one
//! of {response received, response channel dropped (abort), typed
//! `Rejected`} and the counts add up. A hang is a test failure by
//! construction (the scoped threads would never join).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};
use winoq::engine::WinoEngine;
use winoq::nn::layers::Conv2dCfg;
use winoq::nn::tensor::Tensor;
use winoq::serve::{
    with_server, with_shards, EngineModel, ModelRegistry, Rejected, Request, Response,
    ServeConfig, ServeError, ServeQueue, ServeStats, ShardSpec, SubmitOpts,
};
use winoq::testkit::prng_tensor;
use winoq::tune::cost::TileCostModel;
use winoq::wino::basis::Base;

fn good_item(v: f32) -> Tensor {
    Tensor::from_vec(&[1, 2, 2], vec![v; 4])
}

fn bad_item() -> Tensor {
    Tensor::from_vec(&[2, 2], vec![0.0; 4])
}

#[test]
fn submitters_racing_close_and_shape_rejection_account_for_every_request() {
    const SUBMITTERS: usize = 8;
    const PER: usize = 60;
    let q = ServeQueue::with_dims(16, vec![1, 2, 2]);
    let completed = AtomicUsize::new(0);
    let closed = AtomicUsize::new(0);
    let shape = AtomicUsize::new(0);
    let aborted = AtomicUsize::new(0);
    let full_retries = AtomicUsize::new(0);
    std::thread::scope(|s| {
        // One worker echoing inputs back until close-and-drained.
        s.spawn(|| {
            while let Some(batch) = q.next_batch(4, Duration::from_micros(200)) {
                let bsz = batch.len();
                for req in batch {
                    let Request { input, enqueued, tx, .. } = req;
                    let _ = tx.send(Ok(Response {
                        output: input,
                        latency_us: enqueued.elapsed().as_micros() as u64,
                        batch_size: bsz,
                    }));
                }
            }
        });
        for i in 0..SUBMITTERS {
            let (q, completed, closed, shape, aborted, full_retries) =
                (&q, &completed, &closed, &shape, &aborted, &full_retries);
            s.spawn(move || {
                for j in 0..PER {
                    let is_bad = (i + j) % 5 == 0;
                    loop {
                        let input = if is_bad { bad_item() } else { good_item(j as f32) };
                        match q.submit(input) {
                            Ok(rx) => {
                                match rx.recv() {
                                    Ok(res) => {
                                        let resp = res.expect("no cost model: nothing sheds");
                                        assert_eq!(resp.output.dims, vec![1, 2, 2]);
                                        completed.fetch_add(1, Ordering::Relaxed);
                                    }
                                    Err(_) => {
                                        aborted.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                break;
                            }
                            Err(Rejected::Full) => {
                                full_retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                            }
                            Err(Rejected::Closed) => {
                                closed.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(Rejected::Shape { expected, got }) => {
                                assert!(is_bad, "well-formed request shape-rejected");
                                assert_eq!(expected, vec![1, 2, 2]);
                                assert_eq!(got, vec![2, 2]);
                                shape.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                }
            });
        }
        // Close mid-flight: later submissions bounce as Closed while
        // already-admitted requests still drain through the worker.
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(5));
            q.close();
        });
    });
    let total = completed.load(Ordering::Relaxed)
        + closed.load(Ordering::Relaxed)
        + shape.load(Ordering::Relaxed)
        + aborted.load(Ordering::Relaxed);
    assert_eq!(total, SUBMITTERS * PER, "request accounting leaked");
    // close() (not abort) + a draining worker: no admitted request may
    // lose its response.
    assert_eq!(aborted.load(Ordering::Relaxed), 0, "close must drain, not drop");
    assert!(
        shape.load(Ordering::Relaxed) > 0,
        "shape rejection never exercised"
    );
}

#[test]
fn abort_race_fails_all_pending_fast_and_strands_nobody() {
    const SUBMITTERS: usize = 6;
    const PER: usize = 40;
    // No worker at all: the queue fills, submitters spin on Full until a
    // racing abort flips everything to dropped-channel / Closed.
    let q = ServeQueue::new(8);
    let outcomes = AtomicUsize::new(0); // aborted-or-closed, the only legal ends
    let completed = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..SUBMITTERS {
            let (q, outcomes, completed) = (&q, &outcomes, &completed);
            s.spawn(move || {
                for j in 0..PER {
                    loop {
                        match q.submit(good_item(j as f32)) {
                            Ok(rx) => {
                                match rx.recv() {
                                    Ok(_) => completed.fetch_add(1, Ordering::Relaxed),
                                    Err(_) => outcomes.fetch_add(1, Ordering::Relaxed),
                                };
                                break;
                            }
                            Err(Rejected::Full) => std::thread::yield_now(),
                            Err(Rejected::Closed) => {
                                outcomes.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(other) => panic!("unexpected rejection: {other}"),
                        }
                    }
                }
            });
        }
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(2));
            q.abort();
        });
    });
    assert_eq!(completed.load(Ordering::Relaxed), 0, "nothing can complete: no worker");
    assert_eq!(outcomes.load(Ordering::Relaxed), SUBMITTERS * PER);
    // "Fails fast": the whole storm (240 requests × 6 threads) must
    // resolve promptly once aborted, not limp along on timeouts.
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "abort did not fail pending submitters fast"
    );
}

#[test]
fn with_server_under_mixed_load_completes_or_rejects_everything() {
    // Full server machinery (workers + micro-batching + shape-validating
    // queue) under concurrent mixed-shape load, shut down by the client
    // closure returning mid-storm.
    let w = prng_tensor(7, &[3, 2, 3, 3], 0.4);
    let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    let model = EngineModel::new(&engine, conv, [2, 8, 8]);
    let cfg = ServeConfig {
        max_batch: 4,
        batch_window_us: 100,
        queue_cap: 8,
        workers: 2,
        cost: None,
    };
    let stats = ServeStats::new();
    let completed = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    let inputs: Vec<Tensor> = (0..4).map(|i| prng_tensor(100 + i, &[2, 8, 8], 1.0)).collect();
    with_server(&model, &cfg, &stats, |queue| {
        std::thread::scope(|s| {
            for ti in 0..6usize {
                let (queue, completed, rejected, inputs) =
                    (queue, &completed, &rejected, &inputs);
                s.spawn(move || {
                    for j in 0..30usize {
                        let wrong_shape = (ti + j) % 7 == 0;
                        loop {
                            let input = if wrong_shape {
                                good_item(1.0) // [1,2,2] ≠ [2,8,8]
                            } else {
                                inputs[j % inputs.len()].clone()
                            };
                            match queue.submit(input) {
                                Ok(rx) => {
                                    rx.recv()
                                        .expect("worker died mid-session")
                                        .expect("no cost model: nothing sheds");
                                    completed.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                Err(Rejected::Full) => std::thread::yield_now(),
                                Err(Rejected::Shape { .. }) => {
                                    assert!(wrong_shape);
                                    rejected.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                Err(other) => panic!("unexpected rejection: {other}"),
                            }
                        }
                    }
                });
            }
        });
    });
    assert_eq!(
        completed.load(Ordering::Relaxed) + rejected.load(Ordering::Relaxed),
        6 * 30,
        "request accounting leaked under the full server machinery"
    );
    assert!(rejected.load(Ordering::Relaxed) > 0);
    assert_eq!(stats.completed() as usize, completed.load(Ordering::Relaxed));
}

#[test]
fn two_shard_weighted_admission_mixed_shapes_and_forced_shed() {
    // The multi-model soak case: two registry-backed shards behind one
    // weighted admission budget, mixed request geometries (the registry
    // policy admits any 3×H×W ≥ 8), and a slice of hopeless deadlines
    // that must shed with justification. Asserts per-model stats
    // separation, exact accounting, and that the shape-geometry cache
    // keys are namespaced per model (no cross-shard collisions).
    use winoq::nn::{ConvMode, ResNetCfg};

    let cfg_for = |base| ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd { m: 4, base, quant: None },
    };
    let mut reg = ModelRegistry::new();
    let model_a = reg.register_synthetic("a", cfg_for(Base::Legendre), 32, 7, 1).unwrap();
    let model_b = reg.register_synthetic("b", cfg_for(Base::Chebyshev), 32, 9, 1).unwrap();
    // A cost model expensive enough that a 1 µs deadline is always
    // hopeless (fixed 50 µs ≫ 1 µs) while sane deadlines never shed.
    let cost = Some(TileCostModel::new(50.0, 0.05));
    let shard_cfg = ServeConfig {
        max_batch: 4,
        batch_window_us: 200,
        queue_cap: 0, // ignored: the budget decides
        workers: 1,
        cost,
    };
    let specs = [
        ShardSpec { name: "a", model: model_a.as_ref(), weight: 3, cfg: shard_cfg },
        ShardSpec { name: "b", model: model_b.as_ref(), weight: 1, cfg: shard_cfg },
    ];
    let stats = [ServeStats::new(), ServeStats::new()];
    let (mut ok_a, mut shed_a, mut ok_b, mut shed_b, mut rejected) = (0u64, 0u64, 0u64, 0u64, 0u64);
    with_shards(&specs, 8, &stats, |router| {
        let shapes: [&[usize]; 2] = [&[3, 32, 32], &[3, 24, 48]];
        let mut pending = Vec::new();
        for j in 0..24usize {
            let name = if j % 3 == 0 { "b" } else { "a" };
            let hopeless = j % 6 == 5;
            let opts = SubmitOpts {
                deadline_us: if hopeless { Some(1) } else { Some(10_000_000) },
                ..Default::default()
            };
            let x = prng_tensor(200 + j as u64, shapes[j % 2], 1.0);
            match router.submit(name, x, opts) {
                Ok(rx) => pending.push((name, hopeless, rx)),
                Err(Rejected::Full) => rejected += 1,
                Err(other) => panic!("unexpected rejection: {other}"),
            }
        }
        for (name, hopeless, rx) in pending {
            match rx.recv().expect("worker died") {
                Ok(resp) => {
                    assert!(!hopeless, "a 1 µs deadline can never be served in time");
                    assert!(resp.output.data.iter().all(|v| v.is_finite()));
                    if name == "a" {
                        ok_a += 1;
                    } else {
                        ok_b += 1;
                    }
                }
                Err(ServeError::Shed(why)) => {
                    assert!(hopeless, "sane deadlines must not shed");
                    assert!(
                        why.decided_us + why.predicted_us > why.deadline_us,
                        "shed without predicted-cost justification: {why:?}"
                    );
                    if name == "a" {
                        shed_a += 1;
                    } else {
                        shed_b += 1;
                    }
                }
                Err(ServeError::Failed { reason }) => {
                    panic!("no fault injection in this test, yet a batch failed: {reason}")
                }
            }
        }
    });
    // Full accounting: every submission is exactly one of
    // completed / rejected / shed, and the per-shard stats agree.
    assert_eq!(ok_a + ok_b + shed_a + shed_b + rejected, 24);
    assert!(shed_a + shed_b > 0, "the hopeless slice must shed");
    assert_eq!(stats[0].completed(), ok_a, "shard a stats are isolated");
    assert_eq!(stats[1].completed(), ok_b, "shard b stats are isolated");
    assert_eq!(stats[0].report(1.0).shed, shed_a);
    assert_eq!(stats[1].report(1.0).shed, shed_b);
    // The shape-geometry cache is namespaced by model: both shards saw
    // the same two H×W shapes, yet no key collides across shards.
    let keys = reg.plans().shape_keys();
    assert_eq!(keys.len(), 4, "two models × two shapes: {keys:?}");
    for shape in [(32usize, 32usize), (24, 48)] {
        let owners: Vec<&str> = keys
            .iter()
            .filter(|(_, h, w)| (*h, *w) == shape)
            .map(|(m, _, _)| m.as_str())
            .collect();
        assert_eq!(
            owners,
            vec!["a", "b"],
            "shape {shape:?} must have one namespaced key per shard"
        );
    }
}
