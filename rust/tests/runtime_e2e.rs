//! Integration test: load an AOT artifact, run real train + eval steps
//! through PJRT, verify loss decreases on a fixed batch.
use std::path::Path;
use winoq::data::synthcifar;
use winoq::runtime::Artifact;

fn artifacts() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

trait Leak {
    fn leak(self) -> &'static Path;
}
impl Leak for std::path::PathBuf {
    fn leak(self) -> &'static Path {
        Box::leak(self.into_boxed_path())
    }
}

#[test]
fn train_step_reduces_loss_direct() {
    let dir = artifacts();
    let tag = "t2-direct-8b-w0.25";
    if !dir.join(format!("{tag}.manifest.txt")).exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let art = Artifact::load(dir, tag).expect("load artifact");
    let mut state = art.init_state(dir).expect("init state");
    let m = &art.manifest;
    let (imgs, labels) = synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, m.train_batch);
    let labels_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
    let first = art.train_step(&mut state, &imgs.data, &labels_i32, 0.05).unwrap();
    let mut last = first;
    for _ in 0..5 {
        last = art.train_step(&mut state, &imgs.data, &labels_i32, 0.05).unwrap();
    }
    assert!(first.loss.is_finite() && last.loss.is_finite());
    assert!(
        last.loss < first.loss,
        "loss did not fall on a fixed batch: {} -> {}",
        first.loss,
        last.loss
    );

    // eval runs and returns a sane correct-count
    let (eimgs, elabels) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, m.eval_batch);
    let el: Vec<i32> = elabels.iter().map(|&l| l as i32).collect();
    let (eloss, correct) = art.eval_step(&state, &eimgs.data, &el).unwrap();
    assert!(eloss.is_finite());
    assert!((0..=m.eval_batch as i32).contains(&correct));
}

#[test]
fn checkpoint_roundtrip() {
    let dir = artifacts();
    let tag = "t2-direct-8b-w0.25";
    if !dir.join(format!("{tag}.manifest.txt")).exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let art = Artifact::load(dir, tag).unwrap();
    let state = art.init_state(dir).unwrap();
    let bytes = art.state_to_bytes(&state).unwrap();
    let state2 = art.state_from_bytes(&bytes).unwrap();
    let bytes2 = art.state_to_bytes(&state2).unwrap();
    assert_eq!(bytes, bytes2);
    assert_eq!(bytes.len(), art.manifest.total_param_len() * 4);
}
