//! Golden transform vectors (ISSUE 4): the exact Toom-Cook matrices
//! `G / A / Bᵀ` and the base-change pair `P / P⁻¹` are pinned
//! **bit-for-bit** (exact rational equality) against rational-exact JSON
//! fixtures committed under `rust/tests/golden/` — one file per
//! `{canonical, legendre, chebyshev} × m ∈ {2, 4, 6}` (kernel 3×3).
//!
//! The fixtures were derived independently (an exact-arithmetic mirror
//! of the construction, cross-checked against the paper's printed 6×6
//! `Pᵀ`, the integer F(2,3) `Bᵀ` and the monic Legendre/Chebyshev
//! coefficients), so a regression in `wino/{toomcook,poly,basis}.rs` —
//! a reordered point ladder, a changed Lagrange-denominator convention,
//! a recursion slip — fails here against checked-in data that needs no
//! toolchain-era re-derivation.

use std::path::{Path, PathBuf};
use winoq::tune::json::{parse, Json};
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::matrix::RatMat;
use winoq::wino::rational::Rational;
use winoq::wino::toomcook::WinogradPlan;

const BASES: [&str; 3] = ["canonical", "legendre", "chebyshev"];
const MS: [usize; 3] = [2, 4, 6];

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn load(base: &str, m: usize) -> Json {
    let path = golden_dir().join(format!("{base}_m{m}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading golden fixture {path:?}: {e}"));
    parse(&text).unwrap_or_else(|e| panic!("golden fixture {path:?} is not valid JSON: {e}"))
}

/// Parse one `"num/den"` fixture entry into an exact rational.
fn rat(entry: &Json) -> Rational {
    let s = entry.as_str().expect("fixture matrix entries are strings");
    let (num, den) = s.split_once('/').expect("fixture entries are num/den");
    Rational::new(
        num.parse::<i128>().expect("fixture numerator"),
        den.parse::<i128>().expect("fixture denominator"),
    )
}

/// Assert `got` equals the fixture matrix under `key`, entry by entry.
fn assert_matches(doc: &Json, key: &str, got: &RatMat, what: &str) {
    let rows = doc
        .get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("{what}: fixture is missing matrix {key:?}"));
    assert_eq!(rows.len(), got.rows(), "{what}: {key} row count");
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().expect("fixture rows are arrays");
        assert_eq!(row.len(), got.cols(), "{what}: {key} column count");
        for (j, entry) in row.iter().enumerate() {
            let want = rat(entry);
            assert!(
                want == got[(i, j)],
                "{what}: {key}[{i},{j}] = {} but the golden fixture pins {}",
                got[(i, j)],
                want
            );
        }
    }
}

#[test]
fn every_fixture_exists() {
    for base in BASES {
        for m in MS {
            let path = golden_dir().join(format!("{base}_m{m}.json"));
            assert!(path.exists(), "missing golden fixture {path:?}");
        }
    }
}

#[test]
fn toomcook_matrices_match_golden_bit_for_bit() {
    // G/A/Bᵀ depend only on m (the standard point ladder), but every
    // fixture carries them — all nine files must agree with the
    // construction, so a partial regeneration cannot go stale silently.
    for base in BASES {
        for m in MS {
            let doc = load(base, m);
            let plan = WinogradPlan::new(m, 3);
            let what = format!("{base} F({m},3)");
            assert_eq!(doc.get("n").and_then(Json::as_u64), Some(plan.n as u64));
            assert_matches(&doc, "a", &plan.a, &what);
            assert_matches(&doc, "g", &plan.g, &what);
            assert_matches(&doc, "bt", &plan.bt, &what);
        }
    }
}

#[test]
fn base_change_matrices_match_golden_bit_for_bit() {
    for base_name in BASES {
        let base = Base::from_name(base_name).unwrap();
        for m in MS {
            let doc = load(base_name, m);
            let n = m + 2;
            let bc = BaseChange::new(base, n);
            let what = format!("{base_name} n={n}");
            assert_matches(&doc, "p", &bc.p, &what);
            assert_matches(&doc, "p_inv", &bc.p_inv, &what);
        }
    }
}

#[test]
fn fixtures_are_internally_consistent() {
    // Belt and braces on the committed data itself: P·P⁻¹ = I exactly,
    // and the canonical base's P is the identity.
    for base in BASES {
        for m in MS {
            let doc = load(base, m);
            let n = m + 2;
            let to_ratmat = |key: &str| -> RatMat {
                let rows = doc.get(key).and_then(Json::as_arr).unwrap();
                let mut out = RatMat::zeros(rows.len(), n);
                for (i, row) in rows.iter().enumerate() {
                    for (j, entry) in row.as_arr().unwrap().iter().enumerate() {
                        out[(i, j)] = rat(entry);
                    }
                }
                out
            };
            let p = to_ratmat("p");
            let p_inv = to_ratmat("p_inv");
            assert_eq!(p.matmul(&p_inv), RatMat::identity(n), "{base} m={m}: P·P⁻¹ ≠ I");
            if base == "canonical" {
                assert_eq!(p, RatMat::identity(n), "canonical P must be the identity");
            }
        }
    }
}
