//! Deadline-scheduler property suite (ISSUE 6): forall seeded request
//! streams — mixed priorities, deadlines, shapes, tenant weights — the
//! scheduler invariants hold and the accounting is exact, on a virtual
//! clock, deterministically, fast enough for CI.
//!
//! Invariants pinned here (over the *production* scheduler — the soak
//! harness drives the same [`Scheduler`](winoq::serve::Scheduler) the
//! threaded `ServeQueue` embeds):
//!
//! 1. **No late close**: no batch closes later than its earliest
//!    member's deadline minus the predicted batch cost.
//! 2. **Justified shedding**: every shed carries a predicted-cost
//!    justification with `decided + predicted > deadline`.
//! 3. **Exact accounting**: every submitted request ends as exactly one
//!    of completed / rejected / shed, globally and per tenant.
//! 4. **Bounded, homogeneous batches**: `1 ≤ size ≤ max_batch`, one
//!    shape per batch.
//! 5. **Determinism**: one seed, one byte-identical report.

use std::time::Duration;
use winoq::serve::{Poll, Priority, Scheduler, ServeQueue, SubmitOpts};
use winoq::testkit::soak::{run_soak, two_tenant_config, SoakConfig};
use winoq::testkit::{forall, prng_tensor};
use winoq::tune::cost::TileCostModel;
use winoq::wino::error::Prng;

/// Randomized soak configs around the two-tenant fixture: load, deadline
/// tightness, budget, batching and window all vary per case.
fn gen_cfg(rng: &mut Prng) -> SoakConfig {
    let mut cfg = two_tenant_config(rng.next_u64(), 96 + (rng.next_u64() % 320) as usize);
    cfg.mean_gap_us = 5 + rng.next_u64() % 60;
    cfg.deadline_us = 500 + rng.next_u64() % 30_000;
    cfg.tight_pct = (rng.next_u64() % 20) as u32;
    cfg.no_deadline_pct = (rng.next_u64() % 40) as u32;
    cfg.budget = 8 + (rng.next_u64() % 120) as usize;
    cfg.max_batch = 1 + (rng.next_u64() % 12) as usize;
    cfg.window_us = 200 + rng.next_u64() % 4_000;
    cfg.service_jitter_div = 8 + rng.next_u64() % 16;
    cfg
}

#[test]
fn soak_invariants_hold_for_all_seeded_streams() {
    forall(0x5EED_D1CE, 25, gen_cfg, |cfg| {
        let r = run_soak(cfg);
        assert!(r.accounting_exact(), "accounting leaked: {}", r.summary_line());
        for b in &r.batches {
            assert!(b.size >= 1 && b.size <= r.max_batch, "batch size {} out of bounds", b.size);
            if let Some(d) = b.earliest_deadline_us {
                assert!(
                    b.closed_us + b.predicted_us <= d,
                    "batch closed past earliest deadline − predicted cost: {b:?}"
                );
            }
        }
        for s in &r.sheds {
            assert!(
                s.why.decided_us + s.why.predicted_us > s.why.deadline_us,
                "unjustified shed: {s:?}"
            );
            assert_eq!(
                s.item.deadline_us,
                Some(s.why.deadline_us),
                "shed justification must quote the request's own deadline"
            );
        }
        true
    });
}

#[test]
fn soak_reports_are_deterministic_per_seed() {
    let cfg = two_tenant_config(0xD00D, 384);
    let a = run_soak(&cfg);
    let b = run_soak(&cfg);
    assert_eq!(a.to_json(), b.to_json(), "same seed must replay byte-identically");
    let other = run_soak(&two_tenant_config(0xD00E, 384));
    assert_ne!(a.to_json(), other.to_json(), "the seed must steer the trace");
}

/// One randomized direct-scheduler case: submits with random priorities,
/// deadlines and shapes, then drains with advancing virtual time.
#[derive(Debug)]
struct StreamCase {
    seed: u64,
    n: usize,
    cap: usize,
    max_batch: usize,
}

fn gen_stream(rng: &mut Prng) -> StreamCase {
    StreamCase {
        seed: rng.next_u64(),
        n: 16 + (rng.next_u64() % 96) as usize,
        cap: 4 + (rng.next_u64() % 28) as usize,
        max_batch: 1 + (rng.next_u64() % 8) as usize,
    }
}

#[test]
fn scheduler_accounts_for_every_ticket_under_random_streams() {
    let cost = TileCostModel::new(20.0, 1.0);
    forall(0xACC0, 40, gen_stream, |case| {
        let mut rng = Prng::new(case.seed);
        let mut s = Scheduler::new(case.cap);
        let (mut admitted, mut rejected) = (0u64, 0u64);
        let (mut dispatched, mut shed) = (0u64, 0u64);
        let mut now = 0u64;
        let drain = |s: &mut Scheduler, now: u64, flush: bool| {
            let mut served = 0u64;
            let mut dropped = 0u64;
            loop {
                match s.poll(now, case.max_batch, 500, Some(&cost), flush) {
                    Poll::Idle | Poll::WaitUntil(_) => break,
                    Poll::Dispatch { batch, shed } => {
                        assert!(batch.len() <= case.max_batch);
                        assert!(
                            batch.windows(2).all(|p| p[0].shape == p[1].shape),
                            "shape-mixed batch"
                        );
                        served += batch.len() as u64;
                        dropped += shed.len() as u64;
                        if batch.is_empty() && shed.is_empty() {
                            break;
                        }
                    }
                }
            }
            (served, dropped)
        };
        for _ in 0..case.n {
            now += 1 + rng.next_u64() % 40;
            let pri = match rng.next_u64() % 3 {
                0 => Priority::High,
                1 => Priority::Normal,
                _ => Priority::Low,
            };
            let deadline = match rng.next_u64() % 3 {
                0 => None,
                // Sometimes hopeless (below the 20 µs fixed cost floor).
                1 => Some(now + rng.next_u64() % 15),
                _ => Some(now + 100 + rng.next_u64() % 2_000),
            };
            let tiles = 1 + rng.next_u64() % 60;
            let shape = if rng.next_u64() % 2 == 0 { (16, 16) } else { (24, 48) };
            if s.submit(now, pri, deadline, tiles, shape).is_some() {
                admitted += 1;
            } else {
                rejected += 1;
            }
            if rng.next_u64() % 4 == 0 {
                let (d, x) = drain(&mut s, now, false);
                dispatched += d;
                shed += x;
            }
        }
        // Final flush drains everything that remains.
        now += 1_000_000;
        let (d, x) = drain(&mut s, now, true);
        dispatched += d;
        shed += x;
        assert_eq!(s.depth(), 0, "flush must leave nothing pending");
        assert_eq!(admitted + rejected, case.n as u64);
        assert_eq!(
            dispatched + shed,
            admitted,
            "every admitted ticket must dispatch or shed exactly once"
        );
        true
    });
}

#[test]
fn threaded_queue_drains_edf_within_priority_lanes() {
    // The threaded front-end enforces the same policy the pure scheduler
    // proves: priority lanes strictly dominate, EDF inside a lane, FIFO
    // for deadline-free requests — regardless of submit order.
    let q = ServeQueue::with_dims(16, vec![1, 2, 2]);
    let item = |v: f32| prng_tensor(v as u64 + 40, &[1, 2, 2], 1.0);
    let d = |us| SubmitOpts { deadline_us: Some(us), ..Default::default() };
    let _r1 = q.submit_with(item(1.0), d(800_000)).unwrap();
    let _r2 = q
        .submit_with(
            item(2.0),
            SubmitOpts { deadline_us: Some(900_000), priority: Priority::Low },
        )
        .unwrap();
    let _r3 = q.submit_with(item(3.0), d(1_000)).unwrap(); // tightest, Normal
    let _r4 = q.submit_with(item(4.0), SubmitOpts::default()).unwrap(); // deadline-free
    let _r5 = q
        .submit_with(
            item(5.0),
            SubmitOpts { deadline_us: Some(700_000), priority: Priority::High },
        )
        .unwrap();
    let mut order = Vec::new();
    for _ in 0..5 {
        let batch = q.next_batch(1, Duration::ZERO).expect("queue open");
        assert_eq!(batch.len(), 1);
        order.push(batch[0].deadline_us);
    }
    // High lane first (700ms), then Normal EDF (1ms, 800ms), then
    // deadline-free Normal, then the Low lane.
    let got: Vec<bool> = order.iter().map(|d| d.is_some()).collect();
    assert_eq!(got, vec![true, true, true, false, true]);
    // Exact EDF inside the Normal lane: the 1 ms deadline (submitted
    // *after* the 800 ms one) drains first.
    assert!(order[1] < order[2], "EDF violated inside the Normal lane: {order:?}");
}
