//! Arbitrary-H×W parity (ISSUE 6): the serving stack no longer assumes
//! square power-of-two images, so this suite pins the generalized tile
//! geometry against the f64 direct-convolution oracle on non-square,
//! non-divisible-by-`m` shapes — including shapes whose last tile row or
//! column covers a single output pixel.
//!
//! * **Float engines**: `WinoConv2d::forward` must match
//!   [`direct_conv_f64`](winoq::tune::cost::direct_conv_f64) at the
//!   existing float tolerance (`rel_l2 < 1e-3`, in practice ~1e-6) for
//!   every base × `F(2,3)`/`F(4,3)`.
//! * **Integer engines**: the lowered `IntWinoEngine` must stay within
//!   quantization error of the same oracle on the same shapes, and the
//!   serving dispatch (`forward`) must be the integer engine bit-for-bit.
//! * **Tile-grid walk**: `ResNet18::wino_tiles_per_shape` counts the
//!   exact per-stage grids for odd and non-square inputs, and agrees
//!   with the square `wino_tiles_per_item` on the legacy 32×32 path.
//!
//! The 32×32 serving path itself stays bit-identical to pre-PR behavior
//! — that contract is pinned separately in `serve_parity.rs`, which this
//! PR leaves asserting the same bits.

use winoq::nn::layers::Conv2dCfg;
use winoq::nn::winolayer::WinoConv2d;
use winoq::nn::{ConvMode, ResNetCfg};
use winoq::quant::QuantConfig;
use winoq::serve::ModelRegistry;
use winoq::testkit::prng_tensor;
use winoq::tune::cost::{direct_conv_f64, rel_l2};
use winoq::wino::basis::Base;

/// Non-square / non-divisible-by-`m` shape sweep. With `m = 4`, 9 and 13
/// leave a 1-pixel edge tile (9 = 2·4 + 1, 13 = 3·4 + 1); 5×7 is smaller
/// than two tiles in one axis; 12×20 is a clean multiple on both axes to
/// keep one full-grid case in the mix.
const SHAPES: [(usize, usize); 5] = [(9, 13), (13, 9), (10, 10), (5, 7), (12, 20)];

#[test]
fn float_forward_matches_oracle_on_arbitrary_hw() {
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    for (si, &(h, w)) in SHAPES.iter().enumerate() {
        let x = prng_tensor(900 + si as u64, &[2, 3, h, w], 1.0);
        let wt = prng_tensor(950 + si as u64, &[4, 3, 3, 3], 0.4);
        let oracle = direct_conv_f64(&x, &wt, 1);
        for m in [2usize, 4] {
            for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
                let layer = WinoConv2d::new(m, &wt, base);
                let got = layer.forward(&x, conv);
                assert_eq!(
                    got.dims,
                    vec![2, 4, h, w],
                    "{h}x{w} m={m} {base:?}: same-padding shape broke"
                );
                let err = rel_l2(&got.data, &oracle);
                assert!(
                    err < 1e-3,
                    "{h}x{w} m={m} {base:?}: float rel_l2 {err:e} vs f64 oracle"
                );
            }
        }
    }
}

#[test]
fn int_engine_matches_oracle_on_arbitrary_hw_within_quant_error() {
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
        for (si, &(h, w)) in SHAPES.iter().enumerate() {
            let x = prng_tensor(700 + si as u64, &[2, 3, h, w], 1.0);
            let wt = prng_tensor(750 + si as u64, &[4, 3, 3, 3], 0.4);
            let oracle = direct_conv_f64(&x, &wt, 1);
            for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
                let mut layer = WinoConv2d::new(4, &wt, base);
                layer.quantize(qcfg, &x, 1);
                let ie = layer
                    .int_engine()
                    .expect("paper configs fit the i16 code panels");
                // Serving dispatch IS the integer engine, on any shape.
                let got = layer.forward(&x, conv);
                assert_eq!(got.dims, vec![2, 4, h, w]);
                assert_eq!(
                    got.data,
                    ie.forward(&x, conv).data,
                    "{h}x{w} {base:?} {}: forward did not dispatch to the int engine",
                    qcfg.label()
                );
                // Quantization error bound vs the f64 oracle. The bound is
                // a sanity cap, not a precision claim: canonical F(4,3)
                // amplifies transform-domain quantization noise (the
                // paper's motivation), so it gets the loose cap; the
                // orthogonal bases must stay well-conditioned.
                let err = rel_l2(&got.data, &oracle);
                let cap = match base {
                    Base::Canonical => 4.0,
                    _ => 1.0,
                };
                assert!(
                    err < cap,
                    "{h}x{w} {base:?} {}: int rel_l2 {err:e} beyond quant cap {cap}",
                    qcfg.label()
                );
                assert!(
                    err > 0.0,
                    "{h}x{w} {base:?} {}: 8-bit path suspiciously exact",
                    qcfg.label()
                );
            }
        }
    }
}

#[test]
fn resnet_tile_walk_counts_arbitrary_shapes_exactly() {
    // A uniform F(4,3) synthetic ResNet18 has 14 stride-1 wino layers:
    // 5 at full resolution (stem + stage0), then 3 per downsampled stage.
    let mut reg = ModelRegistry::new();
    let cfg = ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None },
    };
    let served = reg.register_synthetic("rn", cfg, 32, 7, 4).unwrap();
    let net = &served.net;
    // Legacy square path unchanged: per-item == per-shape on 32×32.
    assert_eq!(net.wino_tiles_per_item(32), 383);
    assert_eq!(net.wino_tiles_per_shape(32, 32), 383);
    // Odd square: 33 → ⌈33/4⌉² = 81 tiles/layer at full res, then the
    // stride-2 chain 33 → 17 → 9 → 5 gives 25, 9, 4 tiles/layer:
    // 5·81 + 3·25 + 3·9 + 3·4 = 519 (every stage ends in 1-px edge tiles).
    assert_eq!(net.wino_tiles_per_shape(33, 33), 519);
    // Non-square: the walk tracks h and w independently —
    // 5·(9·5) + 3·(5·3) + 3·(3·2) + 3·(2·1) = 294.
    assert_eq!(net.wino_tiles_per_shape(33, 17), 294);
    // Transpose symmetry: every unit is square, so swapping h/w cannot
    // change the tile count.
    assert_eq!(
        net.wino_tiles_per_shape(24, 48),
        net.wino_tiles_per_shape(48, 24)
    );
    assert_eq!(
        net.wino_tiles_per_shape(9, 13),
        net.wino_tiles_per_shape(13, 9)
    );
}
