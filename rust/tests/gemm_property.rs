//! Property suite pinning the register-tiled panel GEMM
//! (`engine::gemm`) **bit-identical** to the retired naive loops — the
//! `*_naive` oracles — over randomized `(C, K, T, N²)` shapes, including
//! every ragged-edge class the packing has to pad: `T % NR ≠ 0`,
//! `K % MR ≠ 0`, `C = 1`, `K = 1`, and tile counts crossing the `NC`
//! cache-block boundary.
//!
//! Float parity is exact-by-construction (the micro-kernel runs the
//! identical `c = 0..C` accumulation chain per `(k, f, t)`, never
//! reassociated — see the `gemm` module docs); the integer path is
//! exact i64 arithmetic plus a requant epilogue that is the same f64
//! operation sequence as `Quantizer::quantize`. These tests are the
//! tripwire that keeps both claims true as the kernels evolve.

use winoq::engine::gemm::{
    panel_gemm_f64, panel_gemm_f64_with, panel_gemm_requant_i16,
    panel_gemm_requant_i16_with, panel_mul_f64_naive, Kernel, Packed, MR, NC, NR,
};
use winoq::engine::int::{panel_mul_requant_i16, panel_mul_requant_i16_naive, PanelDims};
use winoq::quant::scheme::Quantizer;
use winoq::testkit::forall;
use winoq::wino::error::Prng;

/// Documented relative tolerance for the **FMA** f64 kernel variants
/// (`avx2_fma` / `neon_fma`) against the scalar oracle. A fused
/// multiply-add replaces the product's rounding with exact arithmetic,
/// so each of the `C` accumulation steps differs from the scalar chain
/// by at most one ulp of the running sum; with the suite's `C ≤ 9` and
/// O(1)-magnitude operands, `C · 2⁻⁵²` is below `1e-14` — `1e-12` gives
/// two orders of headroom without masking real bugs. Every *non*-FMA
/// variant must match **bitwise** (the float-parity policy in the
/// `gemm` module docs); the FMA variants are never auto-selected, so
/// this tolerance gates opt-in benchmarking only.
const FMA_REL_TOL: f64 = 1e-12;

/// One randomized panel-GEMM case. Shapes are biased toward the ragged
/// classes: `t` and `k` are drawn so non-multiples of `NR`/`MR` dominate.
#[derive(Debug)]
struct Case {
    c: usize,
    k: usize,
    t: usize,
    nn: usize,
    wt: Vec<f64>,
    xt: Vec<f64>,
    /// `Some(scale)` exercises the fused Fig. 2 Hadamard cast.
    fake_scale: Option<f64>,
}

fn gen_case(rng: &mut Prng) -> Case {
    let c = 1 + (rng.next_u64() as usize) % 9;
    let k = 1 + (rng.next_u64() as usize) % (2 * MR + 3);
    let t = 1 + (rng.next_u64() as usize) % (8 * NR + 5);
    let nn = [1usize, 4, 16, 36][(rng.next_u64() as usize) % 4];
    let wt = (0..nn * k * c).map(|_| rng.uniform(0.7)).collect();
    let xt = (0..c * nn * t).map(|_| rng.uniform(1.3)).collect();
    let fake_scale = if rng.next_u64() % 2 == 0 {
        Some(10f64.powf(rng.uniform(2.0) - 2.0))
    } else {
        None
    };
    Case { c, k, t, nn, wt, xt, fake_scale }
}

fn float_case_matches(case: &Case) -> bool {
    let Case { c, k, t, nn, wt, xt, fake_scale } = case;
    let (c, k, t, nn) = (*c, *k, *t, *nn);
    let fake = fake_scale.map(|s| Quantizer::with_scale(9, s));
    let pw = Packed::pack(nn, k, c, 0.0f64, |f, ki, ci| wt[(f * k + ki) * c + ci]);
    let mut tiled = vec![f64::NAN; nn * k * t];
    let mut packs = vec![Vec::new(); 3];
    panel_gemm_f64(&pw, xt, t, fake.as_ref(), &mut tiled, &mut packs);
    let mut naive = vec![0.0f64; nn * k * t];
    panel_mul_f64_naive(wt, PanelDims { c, k, nn }, xt, t, fake.as_ref(), &mut naive);
    tiled
        .iter()
        .zip(&naive)
        .all(|(a, b)| a.to_bits() == b.to_bits())
}

fn int_case_matches(case: &Case, hadamard_bits: u32) -> bool {
    let Case { c, k, t, nn, wt, xt, .. } = case;
    let (c, k, t, nn) = (*c, *k, *t, *nn);
    // Reuse the float case's values as code sources (deterministic,
    // sign-symmetric, tie-prone once scaled).
    let wt_i: Vec<i16> = wt.iter().map(|v| (v * 180.0) as i16).collect();
    let xt_i: Vec<i16> = xt.iter().map(|v| (v * 196.0) as i16).collect();
    let hq = Quantizer::with_scale(hadamard_bits, 3.7e-4);
    let ps = 2.3e-4;
    let dims = PanelDims { c, k, nn };
    let mut tiled = vec![i32::MIN; nn * k * t];
    panel_mul_requant_i16(&xt_i, &wt_i, dims, ps, &hq, &mut tiled);
    let mut naive = vec![0i32; nn * k * t];
    panel_mul_requant_i16_naive(&xt_i, &wt_i, dims, ps, &hq, &mut naive);
    tiled == naive
}

/// Does `kernel` reproduce the oracles on `case`? Float: bitwise for
/// bit-exact variants, within [`FMA_REL_TOL`] for the fused ones. Int:
/// always bitwise.
fn kernel_case_matches(case: &Case, kernel: Kernel) -> bool {
    let Case { c, k, t, nn, wt, xt, fake_scale } = case;
    let (c, k, t, nn) = (*c, *k, *t, *nn);
    let fake = fake_scale.map(|s| Quantizer::with_scale(9, s));
    let pw = Packed::pack(nn, k, c, 0.0f64, |f, ki, ci| wt[(f * k + ki) * c + ci]);
    let mut tiled = vec![f64::NAN; nn * k * t];
    let mut packs = vec![Vec::new(); 3];
    panel_gemm_f64_with(kernel, &pw, xt, t, fake.as_ref(), &mut tiled, &mut packs);
    let mut naive = vec![0.0f64; nn * k * t];
    panel_mul_f64_naive(wt, PanelDims { c, k, nn }, xt, t, fake.as_ref(), &mut naive);
    let float_ok = tiled.iter().zip(&naive).all(|(a, b)| {
        if kernel.f64_bit_exact() {
            a.to_bits() == b.to_bits()
        } else {
            // The fake-quant epilogue snaps both chains to the same code
            // grid most of the time; the tolerance only has to absorb
            // the raw fused-rounding divergence.
            (a - b).abs() <= FMA_REL_TOL * b.abs().max(1.0)
        }
    });
    if !float_ok {
        return false;
    }
    // Int: quantizer-range codes (symmetric, never i16::MIN — the madd
    // precondition documented on `Kernel`).
    let wt_i: Vec<i16> = wt.iter().map(|v| (v * 180.0) as i16).collect();
    let xt_i: Vec<i16> = xt.iter().map(|v| (v * 196.0) as i16).collect();
    let hq = Quantizer::with_scale(9, 3.7e-4);
    let rq = hq.requant(2.3e-4);
    let pwi = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt_i[(f * k + ki) * c + ci]);
    let mut got = vec![i32::MIN; nn * k * t];
    panel_gemm_requant_i16_with(kernel, &pwi, &xt_i, t, &rq, &mut got, &mut [Vec::new()]);
    let mut want = vec![0i32; nn * k * t];
    panel_mul_requant_i16_naive(&xt_i, &wt_i, PanelDims { c, k, nn }, 2.3e-4, &hq, &mut want);
    got == want
}

#[test]
fn forall_tiled_float_gemm_is_bit_identical_to_naive() {
    forall(0xF10A, 120, gen_case, float_case_matches);
}

#[test]
fn forall_every_kernel_variant_matches_the_oracles() {
    // The tentpole's parity gate: every micro-kernel this host can run —
    // scalar always, AVX2/NEON/FMA where detected — against the naive
    // oracles over the ragged shape grid. Int variants must be bitwise;
    // float variants bitwise unless fused (then FMA_REL_TOL). The int
    // run only covers Scalar + the auto-selectable SIMD variant;
    // `Kernel::available_f64()` additionally surfaces the FMA variants.
    let f64_kernels = Kernel::available_f64();
    let i16_kernels = Kernel::available_i16();
    assert!(f64_kernels.contains(&Kernel::Scalar));
    assert!(i16_kernels.contains(&Kernel::Scalar));
    for kernel in f64_kernels {
        forall(0x5EED ^ kernel.name().len() as u64, 40, gen_case, |case| {
            kernel_case_matches(case, kernel)
        });
    }
}

#[test]
fn auto_detected_kernels_are_serve_safe() {
    // Whatever detection picks must be in the bit-exact class — the
    // serve path's float results may never depend on the host's ISA.
    assert!(Kernel::detect_f64().f64_bit_exact());
    let named = ["scalar", "avx2", "neon"];
    assert!(named.contains(&Kernel::detect_f64().name()));
    assert!(named.contains(&Kernel::detect_i16().name()));
}

#[test]
fn forall_tiled_int_gemm_matches_naive_exactly() {
    forall(0x17A0, 80, gen_case, |case| {
        int_case_matches(case, 9) && int_case_matches(case, 8)
    });
}

#[test]
fn pinned_ragged_edges_float_and_int() {
    // The specific shapes the issue calls out, plus NC-crossing widths:
    // each must hold bitwise in float and exactly in int.
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),              // everything degenerate
        (1, MR + 1, NR - 1, 4),    // K ragged, T under one register tile
        (7, 1, NR + 3, 16),        // K = 1, T ragged
        (3, 2 * MR, 4 * NR, 36),   // exact multiples (no padding at all)
        (2, MR - 1, NC + 7, 4),    // T crosses the cache block, K ragged
        (5, MR + 2, 2 * NC, 1),    // nn = 1: the 2-D split is all T-blocks
    ];
    let mut rng = Prng::new(0xED6E);
    for &(c, k, t, nn) in shapes {
        let case = Case {
            c,
            k,
            t,
            nn,
            wt: (0..nn * k * c).map(|_| rng.uniform(0.7)).collect(),
            xt: (0..c * nn * t).map(|_| rng.uniform(1.3)).collect(),
            fake_scale: Some(0.031),
        };
        assert!(float_case_matches(&case), "float parity failed at {c},{k},{t},{nn}");
        assert!(int_case_matches(&case, 9), "int parity failed at {c},{k},{t},{nn}");
    }
}

#[test]
fn engine_level_parity_survives_ragged_filter_counts() {
    // End-to-end guard at a K % MR ≠ 0, C % anything layer: the engine
    // (packed + tiled stage 2) must still be bit-for-bit the per-tile
    // reference — the same invariant engine_parity.rs pins at friendly
    // shapes.
    use winoq::nn::layers::Conv2dCfg;
    use winoq::nn::winolayer::WinoConv2d;
    use winoq::testkit::prng_tensor;
    use winoq::wino::basis::Base;
    let x = prng_tensor(0xAB, &[2, 5, 11, 11], 1.0);
    let w = prng_tensor(0xAC, &[7, 5, 3, 3], 0.4);
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let layer = WinoConv2d::new(4, &w, Base::Chebyshev);
    let reference = layer.forward_reference(&x, cfg);
    let batched = layer.engine().forward(&x, cfg);
    assert_eq!(reference.dims, batched.dims);
    for (i, (a, b)) in reference.data.iter().zip(&batched.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "idx {i}: {a} vs {b}");
    }
}

#[test]
fn direct_packed_driver_matches_raw_slice_entry() {
    // `IntWinoEngine` skips the packing step by calling the packed
    // driver with its bank's pre-packed codes; that route must be the
    // same function as the raw-slice entry the tests above exercise.
    let (c, k, t, nn) = (4, 6, 29, 16);
    let mut rng = Prng::new(0x5151);
    let wt: Vec<i16> = (0..nn * k * c).map(|_| (rng.next_u64() % 255) as i16 - 127).collect();
    let xt: Vec<i16> = (0..c * nn * t).map(|_| (rng.next_u64() % 511) as i16 - 255).collect();
    let hq = Quantizer::with_scale(9, 4.1e-4);
    let ps = 1.1e-4;
    let packed = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt[(f * k + ki) * c + ci]);
    let mut via_packed = vec![0i32; nn * k * t];
    let mut packs = vec![Vec::new(); 2];
    panel_gemm_requant_i16(&packed, &xt, t, &hq.requant(ps), &mut via_packed, &mut packs);
    let mut via_raw = vec![0i32; nn * k * t];
    panel_mul_requant_i16(&xt, &wt, PanelDims { c, k, nn }, ps, &hq, &mut via_raw);
    assert_eq!(via_packed, via_raw);
}

#[test]
fn pool_reuses_threads_across_gemm_dispatches() {
    // The spawn-tax fix itself: repeated panel dispatches must ride the
    // same parked helper threads, never spawn fresh ones per call. A
    // private pool makes the census deterministic regardless of what
    // other tests do to the global pool.
    use std::collections::HashSet;
    use std::sync::Mutex;
    use winoq::engine::pool::WorkerPool;
    let pool = WorkerPool::new(3);
    let seen = Mutex::new(HashSet::new());
    for round in 0..16 {
        pool.dispatch(64, 4, |_item, _slot| {
            seen.lock().unwrap().insert(std::thread::current().id());
        });
        let ids = seen.lock().unwrap().len();
        // Caller + at most 3 pool threads, whatever the round count.
        assert!(ids <= 4, "round {round}: {ids} distinct threads — churn");
    }
    assert!(
        seen.into_inner().unwrap().contains(&std::thread::current().id()),
        "the dispatching thread must participate"
    );
}

#[test]
fn pool_shutdown_is_panic_safe() {
    // A panicking work item must reach the caller as a panic, and the
    // pool must stay serviceable afterwards (workers survive item
    // panics); dropping the pool then joins cleanly instead of hanging.
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use winoq::engine::pool::WorkerPool;
    let pool = WorkerPool::new(2);
    let caught = catch_unwind(AssertUnwindSafe(|| {
        pool.dispatch(32, 3, |item, _slot| {
            if item == 11 {
                panic!("poisoned item {item}");
            }
        });
    }));
    let msg = *caught.expect_err("panic must propagate").downcast::<String>().unwrap();
    assert!(msg.contains("poisoned item 11"), "{msg}");
    // Still alive: a full dispatch completes every item exactly once.
    let hits = AtomicUsize::new(0);
    pool.dispatch(100, 3, |_item, _slot| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 100);
    drop(pool); // must join, not hang or double-panic
}
