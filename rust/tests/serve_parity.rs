//! Serve-path parity: responses that rode a micro-batched engine pass
//! must be **bit-identical** to running the same request alone through
//! `WinoEngine::forward` — for both paper quantization configs
//! (`w8`, `w8_h9` with its 9-bit Hadamard) across the Legendre and
//! Chebyshev bases. This is the contract that makes micro-batching a
//! pure throughput knob: batching changes `T`, never a single tile's
//! arithmetic (per-tile transforms, fixed `c = 0..C` accumulation
//! order, per-plane back-transform).

use winoq::engine::EngineScratch;
use winoq::nn::layers::Conv2dCfg;
use winoq::nn::tensor::Tensor;
use winoq::nn::winolayer::WinoConv2d;
use winoq::nn::{ConvMode, ResNetCfg};
use winoq::quant::QuantConfig;
use winoq::serve::{
    run_closed_loop, BatchModel, EngineModel, ModelRegistry, Response, ServeConfig, ServeStats,
};
use winoq::testkit::prng_tensor;
use winoq::wino::basis::Base;

/// Serve `inputs` through a micro-batching session and hand back the
/// responses in submission order, asserting real batches assembled.
fn serve_all(model: &dyn BatchModel, cfg: &ServeConfig, inputs: &[Tensor]) -> Vec<Response> {
    let stats = ServeStats::new();
    let responses = winoq::serve::with_server(model, cfg, &stats, |queue| {
        // Submit everything before collecting so the worker can drain
        // full micro-batches.
        let rxs: Vec<_> = inputs
            .iter()
            .map(|x| queue.submit(x.clone()).expect("queue sized for the test"))
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    .expect("worker died")
                    .expect("no cost model: nothing sheds")
            })
            .collect::<Vec<Response>>()
    });
    let report = stats.report(1.0);
    assert_eq!(report.completed as usize, inputs.len());
    assert!(
        report.batches < inputs.len() as u64,
        "expected micro-batches to assemble, got {} singleton passes",
        report.batches
    );
    responses
}

#[test]
fn quantized_engine_responses_bit_identical_across_bases_and_configs() {
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let calib = prng_tensor(11, &[2, 3, 12, 12], 1.0);
    let w = prng_tensor(12, &[4, 3, 3, 3], 0.4);
    let inputs: Vec<Tensor> = (0..12)
        .map(|i| prng_tensor(100 + i, &[3, 12, 12], 1.0))
        .collect();
    for base in [Base::Legendre, Base::Chebyshev] {
        for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
            let mut layer = WinoConv2d::new(4, &w, base);
            layer.quantize(qcfg, &calib, 1);
            let engine = layer.engine();
            let model = EngineModel::new(engine, cfg, [3, 12, 12]);
            // Generous window: submissions are µs apart, so batches
            // assemble even on a heavily loaded CI machine.
            let serve_cfg = ServeConfig {
                max_batch: 8,
                batch_window_us: 200_000,
                queue_cap: 32,
                workers: 1,
                cost: None,
            };
            let responses = serve_all(&model, &serve_cfg, &inputs);
            for (x, resp) in inputs.iter().zip(&responses) {
                let single = x.clone().reshape(&[1, 3, 12, 12]);
                let want = engine.forward(&single, cfg);
                assert_eq!(resp.output.dims, want.dims[1..].to_vec());
                for (i, (a, b)) in resp.output.data.iter().zip(&want.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "idx {i}: served {a} vs single-request {b} \
                         [{base:?}, {}]",
                        qcfg.label()
                    );
                }
            }
        }
    }
}

#[test]
fn float_engine_parity_with_concurrent_workers() {
    // Two workers racing over the queue must not change any response.
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let w = prng_tensor(21, &[3, 2, 3, 3], 0.5);
    let layer = WinoConv2d::new(4, &w, Base::Legendre);
    let engine = layer.engine();
    let model = EngineModel::new(engine, cfg, [2, 9, 9]);
    let inputs: Vec<Tensor> = (0..10)
        .map(|i| prng_tensor(300 + i, &[2, 9, 9], 1.0))
        .collect();
    let serve_cfg = ServeConfig {
        max_batch: 4,
        batch_window_us: 500,
        queue_cap: 16,
        workers: 2,
        cost: None,
    };
    let report = run_closed_loop(&model, &serve_cfg, &inputs, 20, 5);
    assert_eq!(report.completed, 20);
    // Deterministic spot check through the full session machinery.
    let stats = ServeStats::new();
    let resp = winoq::serve::with_server(&model, &serve_cfg, &stats, |queue| {
        queue.submit(inputs[0].clone()).unwrap().recv().unwrap().unwrap()
    });
    let want = engine.forward(&inputs[0].clone().reshape(&[1, 2, 9, 9]), cfg);
    assert_eq!(resp.output.data, want.data);
}

#[test]
fn registry_resnet_serving_matches_direct_forward() {
    // End-to-end: a quantized synthetic ResNet18 from the registry,
    // served in micro-batches, must reproduce ResNet18::forward on the
    // single request bit-for-bit (the whole network, not just one layer).
    let mut reg = ModelRegistry::new();
    let cfg = ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd {
            m: 4,
            base: Base::Legendre,
            quant: Some(QuantConfig::w8()),
        },
    };
    let served = reg.register_synthetic("rn", cfg, 32, 7, 4).unwrap();
    let inputs: Vec<Tensor> = (0..6)
        .map(|i| prng_tensor(500 + i, &[3, 32, 32], 1.0))
        .collect();
    let serve_cfg = ServeConfig {
        max_batch: 8,
        batch_window_us: 200_000,
        queue_cap: 16,
        workers: 1,
        cost: None,
    };
    let responses = serve_all(served.as_ref(), &serve_cfg, &inputs);
    let mut scratch = EngineScratch::new();
    for (x, resp) in inputs.iter().zip(&responses) {
        let single = x.clone().reshape(&[1, 3, 32, 32]);
        let want = served.net.forward_with_scratch(&single, &mut scratch);
        assert_eq!(resp.output.dims, vec![10]);
        assert_eq!(
            resp.output.data,
            want.data,
            "served logits diverged from single-request forward"
        );
    }
}
