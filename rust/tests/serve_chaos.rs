//! Self-healing serving (ISSUE 10): deterministic fault injection against
//! the real threaded server. A chaos-panicked batch fails with a typed
//! `ServeError::Failed` (no client ever hangs), the supervisor restarts
//! the worker within its bounded budget and serving recovers; persistent
//! drift walks a layer down the int → float → direct fallback ladder and
//! a quiet period re-arms it. A property sweep replays randomized chaos
//! plans through the virtual-clock soak and demands exact accounting and
//! byte-identical reports every time.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use winoq::engine::EngineScratch;
use winoq::nn::tensor::Tensor;
use winoq::nn::EngineMode;
use winoq::obs::drift::{DriftConfig, DriftMonitor, DriftSample};
use winoq::obs::trace::TraceKind;
use winoq::obs::Tracer;
use winoq::serve::{
    with_server_resilient, BatchModel, FallbackConfig, FallbackController, Resilience,
    ServeConfig, ServeError, ServeStats,
};
use winoq::testkit::chaos::{ChaosConfig, FaultPlan};
use winoq::testkit::forall;
use winoq::testkit::soak::{run_soak, SoakConfig, SoakModel};
use winoq::tune::cost::TileCostModel;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

/// Identity model: enough surface for the queue/supervisor machinery
/// without dragging a real network into the chaos path.
struct EchoModel {
    dims: Vec<usize>,
}

impl BatchModel for EchoModel {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn infer_batch(&self, batch: &Tensor, _scratch: &mut EngineScratch) -> Tensor {
        batch.clone()
    }

    fn tiles_per_item(&self) -> usize {
        1
    }
}

fn item(v: f32) -> Tensor {
    Tensor::from_vec(&[1, 2, 2], vec![v; 4])
}

#[test]
fn injected_panics_fail_only_their_batch_and_serving_recovers() {
    let model = EchoModel { dims: vec![1, 2, 2] };
    let cfg = ServeConfig { max_batch: 1, batch_window_us: 0, ..ServeConfig::default() };
    let stats = ServeStats::new();
    let tracer = Arc::new(Tracer::default());
    // seed 0, panic_every 4 over 16 one-request batches: batches
    // {0, 4, 8, 12} panic — four restarts, inside the default budget
    // of five, so the session must survive to a clean close.
    let chaos = ChaosConfig { panic_every: 4, ..ChaosConfig::default() };
    let res = Resilience {
        chaos: Some(Arc::new(FaultPlan::new(chaos))),
        ..Resilience::default()
    };
    let (mut ok, mut failed) = (0u64, 0u64);
    with_server_resilient(
        &model,
        &cfg,
        &stats,
        Some(tracer.clone()),
        None,
        &res,
        |q| {
            for i in 0..16 {
                let rx = q.submit(item(i as f32)).expect("queue far below capacity");
                match rx.recv().expect("failed batches still answer their clients") {
                    Ok(resp) => {
                        assert_eq!(resp.output.dims, vec![1, 2, 2]);
                        ok += 1;
                    }
                    Err(ServeError::Failed { reason }) => {
                        assert!(
                            reason.contains("chaos: injected worker panic"),
                            "unexpected failure reason: {reason}"
                        );
                        failed += 1;
                    }
                    Err(other) => panic!("no cost model, nothing sheds: {other}"),
                }
            }
        },
    );
    assert_eq!(ok, 12, "healthy batches must serve normally");
    assert_eq!(failed, 4, "exactly the scheduled batches fail");
    assert_eq!(stats.completed(), 12);
    assert_eq!(stats.failed(), 4);
    assert_eq!(stats.worker_restarts(), 4, "one bounded restart per injected panic");
    let report = stats.report(1.0);
    assert_eq!(
        report.submitted,
        report.completed + report.rejected + report.shed + report.failed,
        "exact accounting under chaos"
    );

    // The trace stream tells the same story: four spans terminate in
    // `failed`, four `worker_restart` advisories sit on the reserved
    // span 0, and span accounting still reconciles exactly.
    let acc = tracer.accounting();
    assert!(acc.exact, "trace accounting must reconcile under chaos");
    assert_eq!(acc.completed, 12);
    assert_eq!(acc.failed, 4);
    let events = tracer.drain();
    let failed_spans =
        events.iter().filter(|e| matches!(e.kind, TraceKind::Failed { .. })).count();
    assert_eq!(failed_spans, 4);
    let restarts: Vec<_> = events
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::WorkerRestart { .. }))
        .collect();
    assert_eq!(restarts.len(), 4);
    assert!(
        restarts.iter().all(|e| e.span == 0),
        "worker lifecycle events are process-level (span 0)"
    );
}

#[test]
fn relentless_panics_exhaust_the_budget_and_abort_instead_of_crash_looping() {
    let model = EchoModel { dims: vec![1, 2, 2] };
    let cfg = ServeConfig { max_batch: 1, batch_window_us: 0, ..ServeConfig::default() };
    let stats = ServeStats::new();
    // Every batch panics: the supervisor burns its whole budget and
    // then falls back to the fail-fast abort, re-raising the panic out
    // of the session — a deterministic model bug must not crash-loop.
    let chaos = ChaosConfig { panic_every: 1, ..ChaosConfig::default() };
    let res = Resilience {
        chaos: Some(Arc::new(FaultPlan::new(chaos))),
        ..Resilience::default()
    };
    let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        with_server_resilient(&model, &cfg, &stats, None, None, &res, |q| {
            let mut i = 0u64;
            loop {
                match q.submit(item(i as f32)) {
                    Ok(rx) => {
                        let _ = rx.recv();
                    }
                    Err(_) => break, // aborted: Rejected::Closed
                }
                i += 1;
            }
        });
    }));
    assert!(unwound.is_err(), "an exhausted restart budget re-raises the panic");
    assert_eq!(stats.completed(), 0);
    assert!(stats.failed() >= 1, "at least the first poisoned batch is typed-failed");
    assert_eq!(
        stats.worker_restarts() as u32,
        winoq::serve::RestartPolicy::default().max_restarts,
        "restarts stop exactly at the budget"
    );
}

/// A model whose shadow oracle is a dial: OOD mode reports a rel-L2 far
/// over budget, calm mode far under. `set_layer_mode` records every flip
/// the circuit breaker makes.
struct ModalModel {
    dims: Vec<usize>,
    ood: AtomicBool,
    flips: Mutex<Vec<(String, EngineMode)>>,
}

impl BatchModel for ModalModel {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn infer_batch(&self, batch: &Tensor, _scratch: &mut EngineScratch) -> Tensor {
        batch.clone()
    }

    fn tiles_per_item(&self) -> usize {
        1
    }

    fn drift_probe(&self, _item: &Tensor) -> Vec<DriftSample> {
        let rel_err = if self.ood.load(Ordering::Relaxed) { 1.0 } else { 1e-5 };
        vec![DriftSample {
            layer: "l0".to_string(),
            m: 4,
            base: Base::Legendre,
            weight_bits: 8,
            hadamard_bits: 32,
            rel_err,
        }]
    }

    fn set_layer_mode(&self, layer: &str, mode: EngineMode) -> bool {
        self.flips.lock().unwrap().push((layer.to_string(), mode));
        true
    }
}

#[test]
fn drift_degrades_down_the_ladder_and_a_quiet_period_rearms() {
    let model = ModalModel {
        dims: vec![1, 2, 2],
        ood: AtomicBool::new(true),
        flips: Mutex::new(Vec::new()),
    };
    let cfg = ServeConfig { max_batch: 1, batch_window_us: 0, ..ServeConfig::default() };
    let stats = ServeStats::new();
    let tracer = Arc::new(Tracer::default());
    // Sample every span; budget 1e-4 × headroom 4 → OOD (1.0) violates,
    // calm (1e-5) is comfortably inside.
    let mut dm = DriftMonitor::new(DriftConfig { stride: 1, ..DriftConfig::default() });
    dm.set_budget("l0", Some(1e-4));
    let fb = Arc::new(FallbackController::new(FallbackConfig {
        alerts_to_degrade: 2,
        quiet_to_restore: 3,
    }));
    let res = Resilience { fallback: Some(fb.clone()), ..Resilience::default() };
    with_server_resilient(
        &model,
        &cfg,
        &stats,
        Some(tracer.clone()),
        Some(&dm),
        &res,
        |q| {
            let ask = |v: f32| {
                q.submit(item(v))
                    .expect("queue far below capacity")
                    .recv()
                    .expect("worker alive")
                    .expect("nothing sheds or fails here")
            };
            // Two violations: Int → Float. Two more: Float → Direct.
            for i in 0..4 {
                ask(i as f32);
            }
            assert_eq!(fb.mode("l0"), EngineMode::Direct, "persistent drift bottoms out");
            assert_eq!(fb.degraded(), 1);
            assert_eq!(stats.degraded(), 1, "the serve.degraded gauge tracks the breaker");
            // Calm traffic: three consecutive in-budget samples re-arm
            // the layer straight back to the quantized path.
            model.ood.store(false, Ordering::Relaxed);
            for i in 0..3 {
                ask(100.0 + i as f32);
            }
            assert_eq!(fb.mode("l0"), EngineMode::Int, "quiet period restores the layer");
            assert_eq!(fb.degraded(), 0);
            assert_eq!(stats.degraded(), 0);
        },
    );
    // The breaker's flips landed on the model in ladder order, and the
    // trace carries the matching engaged/cleared advisories.
    let flips = model.flips.lock().unwrap().clone();
    assert_eq!(
        flips,
        vec![
            ("l0".to_string(), EngineMode::Float),
            ("l0".to_string(), EngineMode::Direct),
            ("l0".to_string(), EngineMode::Int),
        ]
    );
    let events = tracer.drain();
    let engaged: Vec<_> = events
        .iter()
        .filter_map(|e| match &e.kind {
            TraceKind::FallbackEngaged { layer, from, to } => {
                Some((layer.clone(), from.clone(), to.clone()))
            }
            _ => None,
        })
        .collect();
    assert_eq!(
        engaged,
        vec![
            ("l0".to_string(), "int".to_string(), "float".to_string()),
            ("l0".to_string(), "float".to_string(), "direct".to_string()),
        ]
    );
    let cleared = events
        .iter()
        .filter(|e| matches!(&e.kind, TraceKind::FallbackCleared { layer, to }
            if layer == "l0" && to == "int"))
        .count();
    assert_eq!(cleared, 1);
}

/// A small two-tenant soak fixture for the property sweep: enough
/// pressure for shed/reject paths to fire, small enough to replay many
/// randomized chaos plans quickly.
fn soak_cfg(seed: u64, chaos: Option<ChaosConfig>) -> SoakConfig {
    SoakConfig {
        seed,
        requests: 192,
        budget: 24,
        max_batch: 4,
        window_us: 800,
        mean_gap_us: 25,
        deadline_us: 20_000,
        tight_pct: 5,
        no_deadline_pct: 10,
        shapes: vec![(32, 32, 64), (16, 16, 16)],
        models: vec![
            SoakModel {
                name: "a".to_string(),
                weight: 2,
                workers: 2,
                cost: TileCostModel::new(40.0, 0.02),
            },
            SoakModel {
                name: "b".to_string(),
                weight: 1,
                workers: 1,
                cost: TileCostModel::new(60.0, 0.03),
            },
        ],
        service_jitter_div: 16,
        drift_stride: 0,
        drift_err_scale: 1.0,
        chaos,
    }
}

#[test]
fn property_randomized_chaos_plans_always_account_exactly_and_replay_identically() {
    // ∀ chaos plans (including panic storms that exhaust restart
    // budgets and retire workers): the soak accounts for every request
    // exactly and the full report replays byte-identically.
    forall(
        0xC4A05,
        8,
        |rng: &mut Prng| {
            (
                rng.next_u64() % 1000,    // chaos schedule seed
                1 + rng.next_u64() % 7,   // panic_every ∈ 1..=7 (always some panics)
                rng.next_u64() % 6,       // latency_every (0 = off)
                rng.next_u64() % 5,       // corrupt_every (0 = off)
                rng.next_u64() % 30,      // burst_every (0 = off)
            )
        },
        |&(seed, panic_every, latency_every, corrupt_every, burst_every)| {
            let chaos = ChaosConfig {
                seed,
                panic_every,
                latency_every,
                latency_us: 1500,
                corrupt_every,
                corrupt_scale: 50.0,
                burst_every,
                burst_len: 6,
                ..ChaosConfig::default()
            };
            let cfg = soak_cfg(0xBADC0DE ^ seed, Some(chaos));
            let r1 = run_soak(&cfg);
            let r2 = run_soak(&cfg);
            r1.accounting_exact()
                && r1.failed > 0
                && r1.submitted == cfg.requests as u64
                && r1.to_json() == r2.to_json()
        },
    );
}

#[test]
fn property_disarmed_chaos_is_invisible() {
    // ∀ seeds: a run with a present-but-disarmed chaos plan is
    // byte-identical to a run with no plan at all — arming is the only
    // thing that may perturb the simulation.
    forall(
        0x0FF,
        6,
        |rng: &mut Prng| rng.next_u64(),
        |&seed| {
            let armed_off = run_soak(&soak_cfg(seed, Some(ChaosConfig::default())));
            let none = run_soak(&soak_cfg(seed, None));
            armed_off.to_json() == none.to_json() && none.failed == 0
        },
    );
}
