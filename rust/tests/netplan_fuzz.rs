//! NetPlan reader robustness (ISSUE 4): adversarial mutations of a valid
//! NetPlan document must be **rejected with `Err`, never a panic and
//! never a misparse**. Three mutation families:
//!
//! * structural damage (truncation, missing required fields, corrupted
//!   values) — guaranteed-invalid, so `from_json` must return `Err`;
//! * random single-byte corruption — may happen to stay valid (flipping
//!   one digit of a seed is still a plan), so the property is: no panic,
//!   and any `Ok` result satisfies every schema invariant and survives a
//!   lossless save/reload round trip (no silent misparse);
//! * value-domain violations (version, `m`, bit widths, percentile,
//!   duplicate layers, width) — each specific validation fires.

use winoq::quant::QuantConfig;
use winoq::tune::netplan::{LayerPlan, NetPlan, NETPLAN_VERSION, SUPPORTED_M};
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

fn sample() -> NetPlan {
    NetPlan {
        version: NETPLAN_VERSION,
        model: "resnet18-synthetic".into(),
        width_mult: 0.25,
        num_classes: 10,
        image_hw: 32,
        seed: 7,
        calib_batch: 4,
        calib_pct: 99.5,
        layers: vec![
            LayerPlan {
                layer: "stem".into(),
                m: 4,
                base: Base::Legendre,
                quant: QuantConfig::w8_h9(),
                tuned_err: Some(0.0025),
                tuned_tiles_per_sec: Some(750000.0),
            },
            LayerPlan {
                layer: "s0b0.conv1".into(),
                m: 2,
                base: Base::Canonical,
                quant: QuantConfig::w8(),
                tuned_err: None,
                tuned_tiles_per_sec: None,
            },
            LayerPlan {
                layer: "s2b1.conv2".into(),
                m: 6,
                base: Base::Chebyshev,
                quant: QuantConfig::w8(),
                tuned_err: Some(0.0075),
                tuned_tiles_per_sec: Some(31250.0),
            },
        ],
    }
}

/// Every schema invariant the reader promises its consumers. An `Ok`
/// plan violating any of these is a misparse.
fn assert_invariants(plan: &NetPlan) {
    assert!(
        (1..=NETPLAN_VERSION).contains(&plan.version),
        "version {} outside the accepted 1..={NETPLAN_VERSION}",
        plan.version
    );
    assert!(plan.calib_pct > 0.0 && plan.calib_pct <= 100.0);
    assert!(plan.width_mult > 0.0 && plan.width_mult.is_finite());
    for (i, l) in plan.layers.iter().enumerate() {
        assert!(SUPPORTED_M.contains(&l.m), "layer {i}: m = {}", l.m);
        for bits in [
            l.quant.act_bits,
            l.quant.weight_bits,
            l.quant.hadamard_bits,
            l.quant.out_bits,
        ] {
            assert!((2..=24).contains(&bits), "layer {i}: {bits} bits");
        }
        assert!(
            !plan.layers[..i].iter().any(|p| p.layer == l.layer),
            "duplicate layer {:?} survived parsing",
            l.layer
        );
        // v2 tuned anchors: absent or in-domain, never NaN/negative.
        if let Some(e) = l.tuned_err {
            assert!(e.is_finite() && e >= 0.0, "layer {i}: tuned_err = {e}");
        }
        if let Some(t) = l.tuned_tiles_per_sec {
            assert!(t.is_finite() && t > 0.0, "layer {i}: tuned_tiles_per_sec = {t}");
        }
    }
}

#[test]
fn every_truncation_errs() {
    let doc = sample().to_json();
    let complete = doc.trim_end().len();
    for len in 0..complete {
        // Truncating inside a multi-byte char can't happen (the writer
        // emits pure ASCII), but guard anyway.
        if !doc.is_char_boundary(len) {
            continue;
        }
        assert!(
            NetPlan::from_json(&doc[..len]).is_err(),
            "prefix of {len} bytes parsed as a complete NetPlan"
        );
    }
}

#[test]
fn every_missing_required_field_errs() {
    let doc = sample().to_json();
    for key in [
        "netplan_version",
        "model",
        "width_mult",
        "num_classes",
        "image_hw",
        "seed",
        "calib",
        "batch",
        "pct",
        "layers",
        "layer",
        "m",
        "base",
        "act_bits",
        "weight_bits",
        "hadamard_bits",
        "out_bits",
    ] {
        // Renaming the key (in every occurrence) makes it missing without
        // breaking JSON structure — the reader must notice, not guess.
        let mutated = doc.replace(&format!("\"{key}\":"), &format!("\"x{key}\":"));
        assert_ne!(mutated, doc, "fixture does not contain {key:?}");
        assert!(
            NetPlan::from_json(&mutated).is_err(),
            "NetPlan parsed without required field {key:?}"
        );
    }
}

#[test]
fn value_domain_violations_err() {
    let doc = sample().to_json();
    let cases: &[(&str, &str)] = &[
        ("\"netplan_version\": 2", "\"netplan_version\": 3"),
        ("\"netplan_version\": 2", "\"netplan_version\": 0"),
        ("\"tuned_err\": 0.0025", "\"tuned_err\": -0.0025"),
        ("\"tuned_err\": 0.0025", "\"tuned_err\": \"tiny\""),
        ("\"tuned_tiles_per_sec\": 750000", "\"tuned_tiles_per_sec\": 0"),
        ("\"tuned_tiles_per_sec\": 750000", "\"tuned_tiles_per_sec\": -1"),
        ("\"m\": 4", "\"m\": 5"),
        ("\"m\": 4", "\"m\": -4"),
        ("\"m\": 4", "\"m\": 4.5"),
        ("\"legendre\"", "\"hermite\""),
        ("\"hadamard_bits\": 9", "\"hadamard_bits\": 1"),
        ("\"hadamard_bits\": 9", "\"hadamard_bits\": 25"),
        ("\"pct\": 99.5", "\"pct\": 0"),
        ("\"pct\": 99.5", "\"pct\": 100.5"),
        ("\"width_mult\": 0.25", "\"width_mult\": 0"),
        ("\"width_mult\": 0.25", "\"width_mult\": -0.25"),
        ("\"seed\": 7", "\"seed\": -7"),
        ("\"seed\": 7", "\"seed\": 9007199254740992"),
        ("\"layer\": \"s0b0.conv1\"", "\"layer\": \"stem\""),
    ];
    for (from, to) in cases {
        let mutated = doc.replace(from, to);
        assert_ne!(&mutated, &doc, "pattern {from:?} not found in fixture");
        assert!(
            NetPlan::from_json(&mutated).is_err(),
            "mutation {from:?} -> {to:?} was accepted"
        );
    }
    // Trailing garbage and non-JSON documents.
    for bad in [
        format!("{doc} trailing"),
        "".to_string(),
        "not json".to_string(),
        "[1, 2, 3]".to_string(),
        "{\"netplan_version\": 2".to_string(),
    ] {
        assert!(NetPlan::from_json(&bad).is_err(), "accepted {bad:?}");
    }
}

#[test]
fn v1_artifacts_load_and_round_trip() {
    // A v1 document (no tuned fields, version 1) is what every pre-v2
    // tuner emitted; it must load with `tuned_* = None` and survive the
    // save/reload round trip bit-for-bit.
    let mut v1 = sample();
    v1.version = 1;
    for l in &mut v1.layers {
        l.tuned_err = None;
        l.tuned_tiles_per_sec = None;
    }
    let doc = v1.to_json();
    assert!(doc.contains("\"netplan_version\": 1"), "{doc}");
    assert!(!doc.contains("tuned_"), "v1 fixture leaked tuned fields: {doc}");
    let loaded = NetPlan::from_json(&doc).expect("v1 artifact must load");
    assert_invariants(&loaded);
    assert_eq!(loaded, v1);
    assert_eq!(loaded.to_json(), doc, "v1 round trip drifted");
    // A v1 document that *does* carry tuned fields is still subject to
    // their domain checks (the fields are version-independent).
    let smuggled = doc.replace(
        "\"out_bits\": 8}",
        "\"out_bits\": 8, \"tuned_err\": -1.0}",
    );
    assert_ne!(smuggled, doc, "fixture shape changed; update the splice");
    assert!(NetPlan::from_json(&smuggled).is_err(), "negative tuned_err accepted");
}

#[test]
fn random_byte_mutations_never_panic_or_misparse() {
    // 4000 single-byte corruptions at PRNG-chosen positions. The parser
    // runs inside this test process: a panic fails the test outright; an
    // Err is the expected outcome; an Ok must be schema-valid and
    // round-trip losslessly through its own writer.
    let doc = sample().to_json();
    let bytes = doc.as_bytes();
    let mut rng = Prng::new(0xF0220);
    let (mut errs, mut oks, mut non_utf8) = (0u32, 0u32, 0u32);
    for _ in 0..4000 {
        let pos = (rng.next_u64() as usize) % bytes.len();
        let byte = (rng.next_u64() % 256) as u8;
        let mut mutated = bytes.to_vec();
        mutated[pos] = byte;
        let Ok(text) = String::from_utf8(mutated) else {
            // from_json takes &str; invalid UTF-8 is rejected upstream.
            non_utf8 += 1;
            continue;
        };
        match NetPlan::from_json(&text) {
            Err(_) => errs += 1,
            Ok(plan) => {
                assert_invariants(&plan);
                let reloaded = NetPlan::from_json(&plan.to_json())
                    .expect("a parsed plan must reserialize losslessly");
                assert_eq!(reloaded, plan, "save/reload round trip drifted");
                oks += 1;
            }
        }
    }
    // The sweep must actually exercise both outcomes (structure breaks
    // far more often than a digit flips to another digit).
    assert!(errs > 100, "only {errs} rejections — mutations too tame");
    assert!(oks > 0, "no mutation stayed valid — invariant arm untested");
    assert_eq!(errs + oks + non_utf8, 4000);
}
