//! Integer-engine vs scalar-oracle parity (ISSUE 4 acceptance): the
//! batched [`IntWinoEngine`] must be **bit-identical** to the `QWino`
//! integer oracles —
//!
//! * [`QWino::forward_int_batch`] (the classic single-channel batch
//!   path, kept untouched as the reference), and
//! * [`QWino::forward_int_batch_mc`] (its multi-channel extension:
//!   i64-exact channel accumulation before one Hadamard requant) —
//!
//! for both paper quant configs (`w8`, `w8_h9`) across the canonical,
//! Legendre and Chebyshev bases, over shapes with edge-clamped tiles,
//! `C ≠ K` and batch > 1.

use std::sync::Arc;
use winoq::engine::int::{IntWeightBank, IntWinoEngine};
use winoq::engine::layout::{extract_tile, TileGrid};
use winoq::nn::layers::{pad_hw, Conv2dCfg};
use winoq::nn::winolayer::{LayerScales, WinoConv2d};
use winoq::quant::{QWino, QuantConfig};
use winoq::testkit::prng_tensor;
use winoq::nn::tensor::Tensor;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;
use winoq::wino::matrix::Mat;

fn fake_mat(m: &Mat, q: &winoq::quant::Quantizer) -> Mat {
    Mat::from_vec(m.rows(), m.cols(), q.fake_all(m.data()))
}

#[test]
fn int_engine_bit_identical_to_single_channel_oracle() {
    // One 6×6 tile per image (padding 0, m = 4), C = K = 1: the engine
    // must reproduce QWino::forward_int_batch exactly, config × base.
    for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let qw = QWino::new(4, 3, base, qcfg);
            // Tiles come from an f32 tensor (the engine's input type) and
            // are lifted to f64 exactly — both sides then see identical
            // values, so parity is bit-for-bit, not cast-for-cast.
            let t_total = 9;
            let batch = prng_tensor(77, &[t_total, 1, 6, 6], 1.0);
            let xs: Vec<Mat> = (0..t_total)
                .map(|t| extract_tile(&batch, t, 0, 0, 0, 6))
                .collect();
            let mut rng = Prng::new(78);
            let ws: Vec<Mat> = (0..9).map(|_| rng.mat(3, 3, 0.5)).collect();
            let s = qw.calibrate(&xs, &ws);
            let w = &ws[0];
            let oracle = qw.forward_int_batch(&xs, w, &s);

            // Engine side: the transformed fake-quantized filter becomes
            // a 1×1 weight bank; StageScales map onto LayerScales.
            let wt = qw.wf.transform_weights(&fake_mat(w, &s.weights));
            let bank =
                IntWeightBank::with_quantizer(&[vec![wt]], s.weights_t);
            let scales = LayerScales {
                input: s.input,
                input_t: s.input_t,
                weights_t: s.weights_t,
                hadamard: s.hadamard,
                output: s.output,
            };
            let engine =
                IntWinoEngine::from_bank(qw.wf.clone(), Arc::new(bank), qcfg, scales);

            // The batch already is the tiles, one per image (padding 0,
            // m = 4 ⇒ exactly one 6×6 tile per 6×6 image).
            let (y, dims) =
                engine.forward_f64(&batch, Conv2dCfg { stride: 1, padding: 0 });
            assert_eq!(dims, [t_total, 1, 4, 4]);
            for (t, want) in oracle.iter().enumerate() {
                for i in 0..4 {
                    for j in 0..4 {
                        let got = y[(t * 16) + i * 4 + j];
                        assert_eq!(
                            got.to_bits(),
                            want[(i, j)].to_bits(),
                            "tile {t} ({i},{j}): engine {got} vs oracle {} \
                             [{base:?} {}]",
                            want[(i, j)],
                            qcfg.label()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn int_engine_bit_identical_to_multichannel_oracle() {
    // Full layer shapes — C ≠ K, batch > 1, 9×9 output (edge-clamped
    // tiles at m = 4): the layer's integer engine must equal the scalar
    // per-tile oracle on every output pixel, config × base × m.
    for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            for m in [2usize, 4] {
                let x = prng_tensor(500 + m as u64, &[2, 3, 9, 9], 1.0);
                let w = prng_tensor(600 + m as u64, &[4, 3, 3, 3], 0.4);
                let conv = Conv2dCfg { stride: 1, padding: 1 };
                let mut layer = WinoConv2d::new(m, &w, base);
                layer.quantize(qcfg, &x, 1);
                let ie = layer.int_engine().expect("paper configs fit the int engine");
                let (y, dims) = ie.forward_f64(&x, conv);
                let [bn, k, oh, ow] = dims;

                let sc = layer.quant.unwrap().1;
                let qw = QWino::with_plan(layer.wf.clone(), qcfg);
                // The mc oracle reads only {input, input_t, weights_t,
                // hadamard, output}; the r×r weights slot is unused by
                // the layer pipeline (WinoConv2d bakes no pre-transform
                // weight cast), so any placeholder quantizer works.
                let s = winoq::quant::StageScales {
                    input: sc.input,
                    weights: winoq::quant::Quantizer::with_scale(8, 1.0),
                    input_t: sc.input_t,
                    weights_t: sc.weights_t,
                    hadamard: sc.hadamard,
                    output: sc.output,
                };

                let padded = pad_hw(&x, 1);
                let grid = TileGrid::new(&padded.dims, m, 3);
                let n = layer.wf.n;
                // Per-tile channel stacks, in engine tile order.
                let mut tiles: Vec<Vec<Mat>> = Vec::with_capacity(grid.tile_count());
                for ni in 0..grid.bn {
                    for th in 0..grid.tiles_h {
                        for tw in 0..grid.tiles_w {
                            tiles.push(
                                (0..3)
                                    .map(|ci| {
                                        extract_tile(&padded, ni, ci, th * m, tw * m, n)
                                    })
                                    .collect(),
                            );
                        }
                    }
                }
                for ki in 0..k {
                    let oracle = qw.forward_int_batch_mc(&tiles, &layer.wt[ki], &s);
                    for ni in 0..bn {
                        for th in 0..grid.tiles_h {
                            for tw in 0..grid.tiles_w {
                                let t = grid.tile_index(ni, th, tw);
                                for i in 0..m {
                                    let oi = th * m + i;
                                    if oi >= oh {
                                        break;
                                    }
                                    for j in 0..m {
                                        let oj = tw * m + j;
                                        if oj >= ow {
                                            break;
                                        }
                                        let got = y[((ni * k + ki) * oh + oi) * ow + oj];
                                        let want = oracle[t][(i, j)];
                                        assert_eq!(
                                            got.to_bits(),
                                            want.to_bits(),
                                            "({ni},{ki},{oi},{oj}): engine {got} vs \
                                             oracle {want} [{base:?} m={m} {}]",
                                            qcfg.label()
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn served_dispatch_is_the_int_engine_and_batch_invariant() {
    // The layer's serving entry points (forward / forward_with_scratch)
    // must be the integer engine's output, and micro-batching must not
    // change any single item's result — the property that lets the serve
    // queue batch quantized requests freely.
    let x = prng_tensor(901, &[3, 4, 12, 12], 1.0);
    let w = prng_tensor(902, &[5, 4, 3, 3], 0.4);
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
    layer.quantize(QuantConfig::w8_h9(), &x, 1);
    let ie = layer.int_engine().unwrap();
    let batched = layer.forward(&x, conv);
    assert_eq!(batched.data, ie.forward(&x, conv).data);
    let item: usize = x.dims[1..].iter().product();
    let row = batched.data.len() / x.dims[0];
    for ni in 0..x.dims[0] {
        let mut dims = x.dims.clone();
        dims[0] = 1;
        let single =
            Tensor::from_vec(&dims, x.data[ni * item..(ni + 1) * item].to_vec());
        let y1 = layer.forward(&single, conv);
        assert_eq!(
            &y1.data[..],
            &batched.data[ni * row..(ni + 1) * row],
            "image {ni}: batching changed the integer result"
        );
    }
}
