//! Serve-path drift acceptance: the shadow-oracle monitor must stay
//! silent on calibrated traffic and fire on out-of-distribution traffic
//! — the same two directions `scripts/ci.sh` gates on the CLI.

use std::collections::BTreeMap;

use winoq::data::synthcifar;
use winoq::nn::{ConvMode, ResNetCfg, Tensor};
use winoq::obs::drift::{DriftConfig, DriftMonitor};
use winoq::obs::{TraceSink, Tracer};
use winoq::quant::QuantConfig;
use winoq::serve::{
    run_closed_loop_observed, BatchModel, ModelRegistry, ServeConfig, ServeStats,
};
use winoq::wino::basis::Base;

const REQUESTS: usize = 48;
const POOL: usize = 8;

fn quantized_cfg() -> ResNetCfg {
    ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd {
            m: 4,
            base: Base::Legendre,
            quant: Some(QuantConfig::w8()),
        },
    }
}

fn input_pool(scale: f32) -> Vec<Tensor> {
    let (batch, _) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, POOL);
    let item = 3 * 32 * 32;
    (0..POOL)
        .map(|i| {
            let mut data = batch.data[i * item..(i + 1) * item].to_vec();
            for v in &mut data {
                *v *= scale;
            }
            Tensor::from_vec(&[3, 32, 32], data)
        })
        .collect()
}

/// Per-layer max rel-L2 over a few in-distribution probes — the same
/// self-calibration `winoq serve --drift-json` performs without a plan.
fn calibrated_monitor(model: &dyn BatchModel, pool: &[Tensor], stride: u64) -> DriftMonitor {
    let mut dm =
        DriftMonitor::new(DriftConfig { stride, ..DriftConfig::default() });
    let mut anchors: BTreeMap<String, f64> = BTreeMap::new();
    for input in pool.iter().take(4) {
        for s in model.drift_probe(input) {
            let a = anchors.entry(s.layer).or_insert(0.0);
            *a = a.max(s.rel_err);
        }
    }
    assert!(!anchors.is_empty(), "quantized net must expose wino layers to probe");
    for (layer, err) in &anchors {
        dm.set_budget(layer, Some(*err));
    }
    dm
}

fn serve_with(drift: &DriftMonitor, inputs: &[Tensor], tracer: Option<std::sync::Arc<Tracer>>) {
    let mut registry = ModelRegistry::new();
    let served = registry
        .register_synthetic("drift-test", quantized_cfg(), 32, 7, 4)
        .expect("register synthetic model");
    let cfg = ServeConfig {
        max_batch: 4,
        batch_window_us: 500,
        queue_cap: 64,
        workers: 1,
        cost: None,
    };
    let stats = ServeStats::new();
    let report = run_closed_loop_observed(
        served.as_ref(),
        &cfg,
        &stats,
        inputs,
        REQUESTS,
        4,
        tracer,
        Some(drift),
    );
    assert_eq!(report.completed as usize, REQUESTS);
}

#[test]
fn calibrated_traffic_raises_no_alerts() {
    let pool = input_pool(1.0);
    let mut registry = ModelRegistry::new();
    let probe_model = registry
        .register_synthetic("probe", quantized_cfg(), 32, 7, 4)
        .expect("register synthetic model");
    let dm = calibrated_monitor(probe_model.as_ref(), &pool, 4);
    assert!(!dm.report_only(), "self-calibration must install budgets");
    serve_with(&dm, &pool, None);
    assert!(dm.sampled() > 0, "stride 4 over {REQUESTS} spans must sample");
    assert_eq!(dm.alerts(), 0, "calibrated traffic must stay within budget:\n{}", dm.to_json());
    let report = dm.to_json();
    assert!(report.contains("\"report_only\": false"));
    assert!(report.contains("\"layer\": "));
}

#[test]
fn out_of_distribution_traffic_alerts_every_budgeted_layer() {
    // Budgets from in-distribution probes, traffic scaled 100x past the
    // quantizers' calibrated ranges.
    let calibrated = input_pool(1.0);
    let mut registry = ModelRegistry::new();
    let probe_model = registry
        .register_synthetic("probe", quantized_cfg(), 32, 7, 4)
        .expect("register synthetic model");
    let dm = calibrated_monitor(probe_model.as_ref(), &calibrated, 4);
    let tracer = std::sync::Arc::new(Tracer::default());
    serve_with(&dm, &input_pool(100.0), Some(tracer.clone()));
    assert!(dm.sampled() > 0);
    assert!(dm.alerts() >= 1, "100x inputs must violate some budget:\n{}", dm.to_json());

    // Every layer that carries a budget must have alerted — OOD input
    // at the stem distorts every downstream activation.
    let report = winoq::tune::json::parse(&dm.to_json()).expect("report parses");
    let layers = report.get("layers").and_then(|l| l.as_arr()).expect("layers array");
    assert!(!layers.is_empty());
    for layer in layers {
        let name = layer.get("layer").and_then(|s| s.as_str()).expect("layer name");
        if layer.get("budget").is_none() {
            continue; // report-only entry (none expected here)
        }
        let alerts = layer.get("alerts").and_then(|a| a.as_u64()).expect("alert count");
        assert!(alerts >= 1, "layer {name} stayed under budget on 100x input");
    }

    // The alerts also land in the trace stream as non-terminal events,
    // so accounting still reconciles exactly.
    let lines = tracer.to_json_lines();
    let traced_alerts = lines.matches("\"event\": \"drift_alert\"").count() as u64;
    assert_eq!(traced_alerts, dm.alerts());
    assert!(tracer.accounting().exact);
}
