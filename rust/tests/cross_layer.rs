//! Cross-layer consistency tests: the rust substrate, the checked-in
//! artifact manifests, and the AOT'd init blobs must all agree — these
//! tests catch drift between `python/compile/*` and `rust/src/*` without
//! needing python at test time.

use std::path::{Path, PathBuf};
use winoq::nn::{ConvMode, ResNet18, ResNetCfg};
use winoq::runtime::Manifest;
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::toomcook::WinogradPlan;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Mirrors the golden matrices hard-coded in python's
/// tests/test_wino_matrices.py: both constructions derive the same
/// F(4,3) Bᵀ and the paper's 6x6 Pᵀ, so L1/L2 and L3 compute with
/// identical constants.
#[test]
fn f43_bt_golden_values() {
    let plan = WinogradPlan::new(4, 3);
    // Spot-check distinctive entries of the F-scaled Bᵀ over the standard
    // ladder {0,1,-1,1/2,-1/2,∞}: row 0 comes from N_0 = (0-1)(0+1)(0-.5)(0+.5)
    // = 1/4 — entry (0,0) must be 1/4 · (V^-T)_{00}.
    // Cheaper and stronger: Bᵀ is exact, so verify the defining identity
    // F⁻¹Bᵀ = V⁻ᵀ by checking Bᵀ·Vᵀ = F on the Vandermonde.
    use winoq::wino::matrix::RatMat;
    use winoq::wino::rational::Rational;
    let n = plan.n;
    // Rebuild V from the points.
    let mut v = RatMat::zeros(n, n);
    for (i, p) in plan.points.iter().enumerate() {
        match p {
            winoq::wino::toomcook::Point::Finite(pv) => {
                for j in 0..n {
                    v[(i, j)] = pv.pow(j as u32);
                }
            }
            winoq::wino::toomcook::Point::Infinity => {
                v[(i, n - 1)] = Rational::ONE;
            }
        }
    }
    let prod = plan.bt.matmul(&v.transpose());
    // Bᵀ Vᵀ = F (diagonal of Lagrange denominators).
    for i in 0..n {
        for j in 0..n {
            if i != j {
                assert!(prod[(i, j)].is_zero(), "Bᵀ·Vᵀ not diagonal at ({i},{j})");
            } else {
                assert!(!prod[(i, j)].is_zero());
            }
        }
    }
}

#[test]
fn paper_pt_matches_python_golden() {
    // The same matrix asserted in python/tests/test_wino_matrices.py.
    let bc = BaseChange::new(Base::Legendre, 6);
    let pt = bc.p.transpose();
    let expect_row4 = [3.0 / 35.0, 0.0, -6.0 / 7.0, 0.0, 1.0, 0.0];
    for (j, &e) in expect_row4.iter().enumerate() {
        assert!((pt[(4, j)].to_f64() - e).abs() < 1e-15);
    }
}

#[test]
fn manifest_matches_rust_model_structure() {
    let dir = artifacts();
    let path = dir.join("t2-direct-8b-w0.25.manifest.txt");
    if !path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = Manifest::load(&path).unwrap();
    // The rust inference model enumerates the same conv units.
    let cfg = ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Direct,
    };
    let units = ResNet18::conv_units(&cfg);
    for (prefix, _stride, cin, cout) in units {
        let ksize = if prefix.ends_with("down") { 1 } else { 3 };
        let spec = m
            .params
            .iter()
            .find(|p| p.name == format!("{prefix}.w"))
            .unwrap_or_else(|| panic!("manifest missing {prefix}.w"));
        assert_eq!(
            spec.dims,
            vec![cout, cin, ksize, ksize],
            "shape mismatch for {prefix}.w"
        );
    }
    assert!(m.params.iter().any(|p| p.name == "fc.w"));
    // Init blob size agrees.
    let blob = std::fs::read(dir.join("t2-direct-8b-w0.25.init.bin")).unwrap();
    assert_eq!(blob.len(), m.total_param_len() * 4);
}

#[test]
fn flex_manifest_has_trainable_matrices() {
    let dir = artifacts();
    let path = dir.join("t2-L-flex-8b-w0.25.manifest.txt");
    if !path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = Manifest::load(&path).unwrap();
    let wino_params: Vec<_> = m
        .params
        .iter()
        .filter(|p| p.name.contains(".wino."))
        .collect();
    // 14 winograd layers x 3 matrices (see python test_flex_params_added).
    assert_eq!(wino_params.len(), 42);
    // Shapes: a_p (6,4), g_p (6,3), bt_p (6,6).
    for p in wino_params {
        if p.name.ends_with("a_p") {
            assert_eq!(p.dims, vec![6, 4]);
        } else if p.name.ends_with("g_p") {
            assert_eq!(p.dims, vec![6, 3]);
        } else {
            assert_eq!(p.dims, vec![6, 6]);
        }
    }
}

#[test]
fn static_and_flex_share_backbone_params() {
    let dir = artifacts();
    let a = dir.join("t2-static-8b-w0.25.manifest.txt");
    let b = dir.join("t2-L-flex-8b-w0.25.manifest.txt");
    if !a.exists() || !b.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ma = Manifest::load(&a).unwrap();
    let mb = Manifest::load(&b).unwrap();
    let backbone_a: Vec<_> = ma.params.iter().filter(|p| !p.name.contains(".wino.")).collect();
    let backbone_b: Vec<_> = mb.params.iter().filter(|p| !p.name.contains(".wino.")).collect();
    assert_eq!(backbone_a.len(), backbone_b.len());
    for (x, y) in backbone_a.iter().zip(&backbone_b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.dims, y.dims);
    }
}
