//! Bench T1 (docs/ARCHITECTURE.md §Experiments): regenerate the paper's **Table 1** — ResNet18
//! x0.5, Winograd F(4x4,3x3), columns {direct, Static, Flex, L-static,
//! L-flex} at 8 bits and 8-bit+9-bit-Hadamard — by actually training every
//! cell's AOT artifact through the rust coordinator on the synthetic-CIFAR
//! workload.
//!
//! Absolute accuracies are NOT comparable to the paper's (synthetic data,
//! short schedule — docs/ARCHITECTURE.md §Experiments); the reproduced quantity is the ordering
//! and the gap structure. The paper's numbers print alongside.
//!
//! Budget: WINOQ_TABLE_STEPS (default 60) training steps per cell; the
//! width-0.5 graphs are the slow ones. Requires `make artifacts`.
//!
//! Run: `cargo bench --bench table1_accuracy`

use winoq::coordinator::experiments::{
    paper_table1, render_table, run_cell_cached, table1, table1_w025, table_train_cfg,
};
use winoq::runtime::artifacts_dir;

fn main() {
    let dir = artifacts_dir();
    let steps: u64 = std::env::var("WINOQ_TABLE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let cfg = table_train_cfg(steps);
    // Wall-clock budget: stop training NEW cells once exceeded (cached cells
    // still print). Compilation dominates on this testbed (docs/ARCHITECTURE.md §Experiments).
    let budget_s: u64 = std::env::var("WINOQ_TABLE_MAX_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3600);
    let started = std::time::Instant::now();
    eprintln!("table 1: {steps} steps per cell (set WINOQ_TABLE_STEPS to change)");

    // WINOQ_T1_WIDTH=0.25 switches to the width-0.25 replica of the grid
    // (single-core testbeds; see docs/ARCHITECTURE.md §Experiments ).
    let width = std::env::var("WINOQ_T1_WIDTH").unwrap_or_else(|_| "0.5".into());
    let grid = if width == "0.25" { table1_w025() } else { table1() };
    let mut rows = Vec::new();
    for (row_label, cells) in grid {
        let mut out = Vec::new();
        for cell in cells {
            if !dir.join(format!("{}.manifest.txt", cell.tag)).exists() {
                eprintln!("SKIP {}: artifact missing (run `make artifacts`)", cell.tag);
                continue;
            }
            if started.elapsed().as_secs() > budget_s
                && !cached(cell.tag, steps)
            {
                eprintln!("BUDGET {}: wall-clock budget exhausted, skipping", cell.tag);
                continue;
            }
            eprintln!("training {}…", cell.tag);
            let t = std::time::Instant::now();
            match run_cell_cached(&dir, cell.tag, &cfg) {
                Ok(acc) => {
                    eprintln!(
                        "  {} -> {:.2}% in {:.0}s",
                        cell.tag,
                        acc * 100.0,
                        t.elapsed().as_secs_f64()
                    );
                    out.push((cell.column.to_string(), acc));
                }
                Err(e) => eprintln!("  {} FAILED: {e:#}", cell.tag),
            }
        }
        rows.push((row_label, out));
    }
    print!(
        "{}",
        render_table(
            "Table 1: ResNet18 x0.5, Winograd F4, synthetic-CIFAR substitute",
            &rows,
            Some(&paper_table1()),
        )
    );
    println!(
        "\nshape checks (paper): static < L-static < flex ≤ L-flex ≤ direct;\n\
         9-bit Hadamard row ≥ its 8-bit counterpart, closing the direct gap."
    );
}

/// Is this (tag, steps) already in the result cache?
fn cached(tag: &str, steps: u64) -> bool {
    std::fs::read_to_string("out/table_cache.csv")
        .map(|t| {
            t.lines()
                .any(|l| l.starts_with(&format!("{tag},{steps},")))
        })
        .unwrap_or(false)
}
