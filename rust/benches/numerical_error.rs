//! Bench M1 (docs/ARCHITECTURE.md §Experiments): numerical error vs tile size and base, plus
//! transform condition numbers — regenerates the paper's §1/§4.1 motivating
//! claims as a table.
//!
//! Run: `cargo bench --bench numerical_error`

use winoq::quant::{QWino, QuantConfig};
use winoq::wino::basis::Base;
use winoq::wino::error::{condition_numbers, measure_tile_error};

fn main() {
    println!("== M1a: fp32 pipeline, mean rel L2 error vs f64 direct oracle ==");
    println!(
        "{:>8} {:>13} {:>13} {:>13} {:>14}",
        "tile", "canonical", "legendre", "chebyshev", "growth(can)"
    );
    let mut prev = None;
    for m in [2usize, 4, 6, 8] {
        let e_can = measure_tile_error(m, 3, Base::Canonical, 400, 42).mean_rel_l2;
        let e_leg = measure_tile_error(m, 3, Base::Legendre, 400, 42).mean_rel_l2;
        let e_che = measure_tile_error(m, 3, Base::Chebyshev, 400, 42).mean_rel_l2;
        let growth = prev.map(|p: f64| e_can / p).unwrap_or(f64::NAN);
        println!(
            "{:>8} {:>13.3e} {:>13.3e} {:>13.3e} {:>13.1}x",
            format!("F({m},3)"),
            e_can,
            e_leg,
            e_che,
            growth
        );
        prev = Some(e_can);
    }
    println!("(the ≥exponential error growth with tile size — paper §1, Pan 2016)");

    println!("\n== M1b: condition numbers κ₂ of the transforms ==");
    println!(
        "{:>8} | {:>10} {:>10} | {:>10} {:>10} | {:>10} {:>10}",
        "tile", "κBᵀ can", "κBᵀ leg", "κG can", "κG leg", "κA can", "κA leg"
    );
    for m in [2usize, 4, 6, 8] {
        let c = condition_numbers(m, 3, Base::Canonical);
        let l = condition_numbers(m, 3, Base::Legendre);
        println!(
            "{:>8} | {:>10.2} {:>10.2} | {:>10.2} {:>10.2} | {:>10.2} {:>10.2}",
            format!("F({m},3)"),
            c.kappa_bt,
            l.kappa_bt,
            c.kappa_g,
            l.kappa_g,
            c.kappa_a,
            l.kappa_a
        );
    }

    println!("\n== M1c: quantized-pipeline error (matrices + values quantized) ==");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12}",
        "tile", "bits", "canonical", "legendre", "leg/can"
    );
    for m in [2usize, 4, 6] {
        for bits in [6u32, 8, 10] {
            let cfg = QuantConfig::uniform(bits);
            let e_can = QWino::new_quantized_mats(m, 3, Base::Canonical, cfg, bits)
                .measure_error(300, 17);
            let e_leg = QWino::new_quantized_mats(m, 3, Base::Legendre, cfg, bits)
                .measure_error(300, 17);
            println!(
                "{:>8} {:>6} {:>12.4} {:>12.4} {:>11.3}",
                format!("F({m},3)"),
                bits,
                e_can,
                e_leg,
                e_leg / e_can
            );
        }
    }

    println!("\n== M1d: the Hadamard-bits knob at F(4,3), 8-bit everything else ==");
    println!("{:>10} {:>12} {:>12}", "hadamard", "canonical", "legendre");
    for hbits in [8u32, 9, 10, 12] {
        let cfg = QuantConfig { hadamard_bits: hbits, ..QuantConfig::w8() };
        let e_can =
            QWino::new_quantized_mats(4, 3, Base::Canonical, cfg, 8).measure_error(400, 23);
        let e_leg =
            QWino::new_quantized_mats(4, 3, Base::Legendre, cfg, 8).measure_error(400, 23);
        println!("{hbits:>9}b {e_can:>12.4} {e_leg:>12.4}");
    }
    println!("(paper §5–§6: 9-bit Hadamard closes the accuracy gap)");
}
