//! Bench T2 (docs/ARCHITECTURE.md §Experiments): regenerate the paper's **Table 2** — the same
//! column set at 8 bits for width multipliers 0.25 and 0.5 (the 0.5 row
//! reuses the Table 1 artifacts).
//!
//! Run: `cargo bench --bench table2_accuracy`
//! Budget: WINOQ_TABLE_STEPS (default 60) steps per cell.

use winoq::coordinator::experiments::{render_table, run_cell_cached, table2, table_train_cfg};
use winoq::runtime::artifacts_dir;

fn main() {
    let dir = artifacts_dir();
    let steps: u64 = std::env::var("WINOQ_TABLE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let cfg = table_train_cfg(steps);
    // Wall-clock budget: stop training NEW cells once exceeded (cached cells
    // still print). Compilation dominates on this testbed (docs/ARCHITECTURE.md §Experiments).
    let budget_s: u64 = std::env::var("WINOQ_TABLE_MAX_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3600);
    let started = std::time::Instant::now();
    eprintln!("table 2: {steps} steps per cell (set WINOQ_TABLE_STEPS to change)");

    let mut rows = Vec::new();
    for (row_label, cells) in table2() {
        let mut out = Vec::new();
        for cell in cells {
            if !dir.join(format!("{}.manifest.txt", cell.tag)).exists() {
                eprintln!("SKIP {}: artifact missing (run `make artifacts`)", cell.tag);
                continue;
            }
            if started.elapsed().as_secs() > budget_s
                && !cached(cell.tag, steps)
            {
                eprintln!("BUDGET {}: wall-clock budget exhausted, skipping", cell.tag);
                continue;
            }
            eprintln!("training {}…", cell.tag);
            let t = std::time::Instant::now();
            match run_cell_cached(&dir, cell.tag, &cfg) {
                Ok(acc) => {
                    eprintln!(
                        "  {} -> {:.2}% in {:.0}s",
                        cell.tag,
                        acc * 100.0,
                        t.elapsed().as_secs_f64()
                    );
                    out.push((cell.column.to_string(), acc));
                }
                Err(e) => eprintln!("  {} FAILED: {e:#}", cell.tag),
            }
        }
        rows.push((row_label, out));
    }
    // Paper Table 2 reference values (rows: width mult; the 0.25 row of the
    // paper is partially garbled in the source — the direct column 90.2%
    // and L-flex 89.7% are the legible anchors).
    print!(
        "{}",
        render_table(
            "Table 2: widths 0.25 / 0.5, 8-bit quantization",
            &rows,
            None,
        )
    );
    println!(
        "paper anchors: width 0.25 direct 90.2%, L-flex 89.7%; width 0.5\n\
         direct 92.3%, L-flex 91.8% — reproduce the ordering, not the values."
    );
}

/// Is this (tag, steps) already in the result cache?
fn cached(tag: &str, steps: u64) -> bool {
    std::fs::read_to_string("out/table_cache.csv")
        .map(|t| {
            t.lines()
                .any(|l| l.starts_with(&format!("{tag},{steps},")))
        })
        .unwrap_or(false)
}
