//! Bench M3 (docs/ARCHITECTURE.md §Experiments): layer-level throughput —
//! direct conv vs the per-tile Winograd reference vs the batched
//! [`WinoEngine`] (canonical/Legendre, float/quantized) on realistic
//! ResNet-stage shapes, reporting tiles/sec for the Winograd paths.
//!
//! Three claims are on the line:
//! * the paper's §1 arithmetic argument — Winograd's reduced
//!   multiplication count (2.25 vs 9 mults/output for F(4,3)) yields real
//!   speedups over direct convolution;
//! * the engine acceptance bar — the batched flat-buffer engine must be
//!   ≥ 3× faster than the per-tile reference path on the ResNet18-shaped
//!   layer (C=K=64, 32×32, batch 8), from GEMM-shaped panels, scratch
//!   reuse and thread parallelism (set `WINOQ_THREADS=1` to isolate the
//!   layout win from the threading win);
//! * the micro-kernel acceptance bar — the register-tiled panel GEMM
//!   (`engine::gemm`) must be ≥ 1.5× faster than the naive stage-2 loops
//!   on both the float and integer kernels (`BENCH_gemm.json`), while
//!   staying bit-identical to them.
//!
//! Engine runs also print the per-stage wall-time breakdown
//! (input-transform / hadamard / inverse) accumulated in the
//! [`EngineScratch`], the same `stage_ns` view `winoq serve` exports in
//! its stats JSON.
//!
//! Run: `cargo bench --bench conv_throughput`

use winoq::benchkit;
use winoq::engine::gemm;
use winoq::engine::int::int_vs_float_bench_json;
use winoq::engine::EngineScratch;
use winoq::nn::layers::{conv2d, Conv2dCfg};
use winoq::nn::tensor::Tensor;
use winoq::nn::winolayer::WinoConv2d;
use winoq::quant::QuantConfig;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

fn rand_tensor(rng: &mut Prng, dims: &[usize], scale: f64) -> Tensor {
    let n = dims.iter().product();
    Tensor::from_vec(dims, (0..n).map(|_| rng.uniform(scale) as f32).collect())
}

/// Render the scratch's cumulative stage breakdown (input-transform /
/// hadamard / inverse wall-ns with percentages) and reset it — the
/// per-stage view that tells future perf PRs *which* stage moved.
fn print_stage_breakdown(label: &str, scratch: &mut EngineScratch) {
    let s = scratch.take_stage_ns();
    let total = (s[0] + s[1] + s[2]).max(1);
    let pct = |v: u64| 100.0 * v as f64 / total as f64;
    println!(
        "  stages [{label}]: input-transform {} ns ({:.1}%) | hadamard {} ns ({:.1}%) \
         | inverse {} ns ({:.1}%)",
        s[0],
        pct(s[0]),
        s[1],
        pct(s[1]),
        s[2],
        pct(s[2]),
    );
}

/// Per-stage sweep: direct vs engine-backed Winograd layer on single images.
fn stage_shapes(rng: &mut Prng) {
    // ResNet-stage shapes at width 0.5 (paper's Table 1 model): C=K, HxW.
    let shapes: &[(usize, usize)] = &[(32, 32), (64, 16), (128, 8)];
    let cfg = Conv2dCfg { stride: 1, padding: 1 };

    for &(c, hw) in shapes {
        let x = rand_tensor(rng, &[1, c, hw, hw], 1.0);
        let w = rand_tensor(rng, &[c, c, 3, 3], 0.2);
        let outputs = (c * hw * hw) as f64;

        let s_direct = benchkit::bench(2, 8, || conv2d(&x, &w, None, cfg));
        benchkit::report(
            &format!("direct 3x3 C={c} {hw}x{hw}"),
            &s_direct,
            Some((outputs, "out-px")),
        );

        for base in [Base::Canonical, Base::Legendre] {
            let layer = WinoConv2d::new(4, &w, base);
            let tiles = layer.engine().tile_count_for(&x.dims, cfg.padding) as f64;
            let mut scratch = EngineScratch::new();
            let s = benchkit::bench(2, 8, || layer.forward_with_scratch(&x, cfg, &mut scratch));
            benchkit::report(
                &format!("wino F4 {} C={c} {hw}x{hw}", base.name()),
                &s,
                Some((tiles, "tiles")),
            );
            benchkit::report_speedup("", &s_direct, &s);
        }

        // Quantized Legendre layer (Fig. 2 casts on the hot path).
        let mut qlayer = WinoConv2d::new(4, &w, Base::Legendre);
        qlayer.quantize(QuantConfig::w8(), &x, 1);
        let tiles = qlayer.engine().tile_count_for(&x.dims, cfg.padding) as f64;
        let mut scratch = EngineScratch::new();
        let s_q = benchkit::bench(2, 8, || qlayer.forward_with_scratch(&x, cfg, &mut scratch));
        benchkit::report(
            &format!("wino F4 legendre int8 C={c} {hw}x{hw}"),
            &s_q,
            Some((tiles, "tiles")),
        );
        println!();
    }
}

/// Engine acceptance shape: C=K=64, 32×32, batch 8 — batched engine vs
/// the per-tile reference path (the seed implementation).
fn engine_vs_per_tile(rng: &mut Prng) {
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let x = rand_tensor(rng, &[8, 64, 32, 32], 1.0);
    let w = rand_tensor(rng, &[64, 64, 3, 3], 0.2);
    let layer = WinoConv2d::new(4, &w, Base::Legendre);
    let tiles = layer.engine().tile_count_for(&x.dims, cfg.padding) as f64;

    println!("── engine acceptance shape: C=K=64 32x32 batch=8 ({tiles} tiles) ──");
    let s_ref = benchkit::bench(1, 5, || layer.forward_reference(&x, cfg));
    benchkit::report("per-tile reference (seed path)", &s_ref, Some((tiles, "tiles")));

    let mut scratch = EngineScratch::new();
    let s_eng = benchkit::bench(1, 5, || layer.forward_with_scratch(&x, cfg, &mut scratch));
    benchkit::report("batched engine (flat buffers)", &s_eng, Some((tiles, "tiles")));
    print_stage_breakdown("float engine, warmup+samples", &mut scratch);
    benchkit::report_speedup("engine vs per-tile", &s_ref, &s_eng);

    let ok = benchkit::speedup(&s_ref, &s_eng) >= 3.0;
    println!(
        "acceptance (engine ≥ 3x per-tile): {}",
        if ok { "PASS" } else { "FAIL" }
    );

    // Sanity on the measured run: both paths agree bit-for-bit.
    let yr = layer.forward_reference(&x, cfg);
    let ye = layer.forward_with_scratch(&x, cfg, &mut scratch);
    assert_eq!(yr.data, ye.data, "engine/per-tile outputs diverged");
    println!();
}

/// Register-tiled panel GEMM vs the naive oracles on the ResNet18
/// acceptance shape, emitting `BENCH_gemm.json` (path override:
/// `WINOQ_BENCH_GEMM`) — the same emitter `winoq bench --gemm-json`
/// runs, and the run asserts tiled/naive bit-parity on the measured
/// buffers. Acceptance bar: ≥ 1.5× tiles/sec on both the float and the
/// integer kernel.
fn gemm_tiled_vs_naive() {
    // C = K = 64, 32×32, batch 8, F(4,3): T = 512 tiles, N² = 36.
    println!("── panel GEMM: tiled vs naive, C=K=64 T=512 N²=36 ──");
    let (json, fr, ir) = gemm::gemm_bench_json(64, 64, 512, 36, 1, 5);
    println!("{json}");
    println!(
        "acceptance (tiled ≥ 1.5x naive tiles/s): float {} ({fr:.2}x), int {} ({ir:.2}x)",
        if fr >= 1.5 { "PASS" } else { "FAIL" },
        if ir >= 1.5 { "PASS" } else { "FAIL" },
    );
    let path =
        std::env::var("WINOQ_BENCH_GEMM").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    match std::fs::write(&path, json + "\n") {
        Ok(()) => println!("BENCH_gemm.json written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    println!();
}

/// Integer engine vs the dequantize-to-float path on the acceptance
/// shape, emitting `BENCH_int.json` (path override: `WINOQ_BENCH_INT`).
/// Acceptance bar: the integer path delivers ≥ 2× tiles/sec.
fn int_vs_dequantize_float(rng: &mut Prng) {
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let x = rand_tensor(rng, &[8, 64, 32, 32], 1.0);
    let w = rand_tensor(rng, &[64, 64, 3, 3], 0.2);
    let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
    layer.quantize(QuantConfig::w8_h9(), &x, 1);
    println!("── integer engine vs dequantize-to-float: w8_h9, C=K=64 32x32 batch=8 ──");
    let (json, ratio) = int_vs_float_bench_json(&layer, &x, cfg, 1, 5);
    println!("{json}");
    println!(
        "acceptance (int ≥ 2x float tiles/s): {} ({ratio:.2}x)",
        if ratio >= 2.0 { "PASS" } else { "FAIL" }
    );
    let path =
        std::env::var("WINOQ_BENCH_INT").unwrap_or_else(|_| "BENCH_int.json".to_string());
    match std::fs::write(&path, json + "\n") {
        Ok(()) => println!("BENCH_int.json written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    println!();
}

fn main() {
    // Warm the persistent engine pool before any timed region so the
    // first sample doesn't eat thread creation.
    winoq::engine::pool::warm();
    let mut rng = Prng::new(9);
    engine_vs_per_tile(&mut rng);
    gemm_tiled_vs_naive();
    int_vs_dequantize_float(&mut rng);
    stage_shapes(&mut rng);
    println!("note: the arithmetic-count advantage is 9/2.25 = 4.0x; the measured");
    println!("ratio reflects this CPU's memory behaviour and the naive direct loop.");
    println!(
        "threads: {} (override with WINOQ_THREADS); gemm kernels: float={} int={} \
         (WINOQ_NO_SIMD=1 forces scalar)",
        winoq::engine::parallel::num_threads(),
        winoq::engine::gemm::Kernel::detect_f64().name(),
        winoq::engine::gemm::Kernel::detect_i16().name(),
    );
}
