//! Bench M3 (DESIGN.md §6): layer-level throughput — direct conv vs the
//! Winograd layer (canonical/Legendre, float/quantized) on realistic
//! ResNet-stage shapes. Checks the paper's §1 claim that Winograd's
//! reduced multiplication count yields real speedups (up to ~4x on
//! mobile CPUs in ref [6]; here: whatever this CPU + naive direct conv
//! gives — the *ratio* is the point).
//!
//! Run: `cargo bench --bench conv_throughput`

use winoq::benchkit;
use winoq::nn::layers::{conv2d, Conv2dCfg};
use winoq::nn::tensor::Tensor;
use winoq::nn::winolayer::WinoConv2d;
use winoq::quant::QuantConfig;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

fn rand_tensor(rng: &mut Prng, dims: &[usize], scale: f64) -> Tensor {
    let n = dims.iter().product();
    Tensor::from_vec(dims, (0..n).map(|_| rng.uniform(scale) as f32).collect())
}

fn main() {
    let mut rng = Prng::new(9);
    // ResNet-stage shapes at width 0.5 (paper's Table 1 model): C=K, HxW.
    let shapes: &[(usize, usize)] = &[(32, 32), (64, 16), (128, 8)];
    let cfg = Conv2dCfg { stride: 1, padding: 1 };

    for &(c, hw) in shapes {
        let x = rand_tensor(&mut rng, &[1, c, hw, hw], 1.0);
        let w = rand_tensor(&mut rng, &[c, c, 3, 3], 0.2);
        let outputs = (c * hw * hw) as f64;

        let s_direct = benchkit::bench(2, 8, || conv2d(&x, &w, None, cfg));
        benchkit::report(
            &format!("direct 3x3 C={c} {hw}x{hw}"),
            &s_direct,
            Some((outputs, "out-px")),
        );

        for base in [Base::Canonical, Base::Legendre] {
            let layer = WinoConv2d::new(4, &w, base);
            let s = benchkit::bench(2, 8, || layer.forward(&x, cfg));
            benchkit::report(
                &format!("wino F4 {} C={c} {hw}x{hw}", base.name()),
                &s,
                Some((outputs, "out-px")),
            );
            println!(
                "{:<44} speedup vs direct: {:.2}x",
                "",
                s_direct.median / s.median
            );
        }

        // Quantized Legendre layer (Fig. 2 casts on the hot path).
        let mut qlayer = WinoConv2d::new(4, &w, Base::Legendre);
        qlayer.quantize(QuantConfig::w8(), &x, 1);
        let s_q = benchkit::bench(2, 8, || qlayer.forward(&x, cfg));
        benchkit::report(
            &format!("wino F4 legendre int8 C={c} {hw}x{hw}"),
            &s_q,
            Some((outputs, "out-px")),
        );
        println!();
    }

    println!("note: the arithmetic-count advantage is 9/2.25 = 4.0x; the measured");
    println!("ratio reflects this CPU's memory behaviour and the naive direct loop.");
}
