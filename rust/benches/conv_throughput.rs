//! Bench M3 (docs/ARCHITECTURE.md §Experiments): layer-level throughput —
//! direct conv vs the per-tile Winograd reference vs the batched
//! [`WinoEngine`] (canonical/Legendre, float/quantized) on realistic
//! ResNet-stage shapes, reporting tiles/sec for the Winograd paths.
//!
//! Two claims are on the line:
//! * the paper's §1 arithmetic argument — Winograd's reduced
//!   multiplication count (2.25 vs 9 mults/output for F(4,3)) yields real
//!   speedups over direct convolution;
//! * the engine acceptance bar — the batched flat-buffer engine must be
//!   ≥ 3× faster than the per-tile reference path on the ResNet18-shaped
//!   layer (C=K=64, 32×32, batch 8), from GEMM-shaped panels, scratch
//!   reuse and thread parallelism (set `WINOQ_THREADS=1` to isolate the
//!   layout win from the threading win).
//!
//! Run: `cargo bench --bench conv_throughput`

use winoq::benchkit;
use winoq::engine::int::int_vs_float_bench_json;
use winoq::engine::EngineScratch;
use winoq::nn::layers::{conv2d, Conv2dCfg};
use winoq::nn::tensor::Tensor;
use winoq::nn::winolayer::WinoConv2d;
use winoq::quant::QuantConfig;
use winoq::wino::basis::Base;
use winoq::wino::error::Prng;

fn rand_tensor(rng: &mut Prng, dims: &[usize], scale: f64) -> Tensor {
    let n = dims.iter().product();
    Tensor::from_vec(dims, (0..n).map(|_| rng.uniform(scale) as f32).collect())
}

/// Per-stage sweep: direct vs engine-backed Winograd layer on single images.
fn stage_shapes(rng: &mut Prng) {
    // ResNet-stage shapes at width 0.5 (paper's Table 1 model): C=K, HxW.
    let shapes: &[(usize, usize)] = &[(32, 32), (64, 16), (128, 8)];
    let cfg = Conv2dCfg { stride: 1, padding: 1 };

    for &(c, hw) in shapes {
        let x = rand_tensor(rng, &[1, c, hw, hw], 1.0);
        let w = rand_tensor(rng, &[c, c, 3, 3], 0.2);
        let outputs = (c * hw * hw) as f64;

        let s_direct = benchkit::bench(2, 8, || conv2d(&x, &w, None, cfg));
        benchkit::report(
            &format!("direct 3x3 C={c} {hw}x{hw}"),
            &s_direct,
            Some((outputs, "out-px")),
        );

        for base in [Base::Canonical, Base::Legendre] {
            let layer = WinoConv2d::new(4, &w, base);
            let tiles = layer.engine().tile_count_for(&x.dims, cfg.padding) as f64;
            let mut scratch = EngineScratch::new();
            let s = benchkit::bench(2, 8, || layer.forward_with_scratch(&x, cfg, &mut scratch));
            benchkit::report(
                &format!("wino F4 {} C={c} {hw}x{hw}", base.name()),
                &s,
                Some((tiles, "tiles")),
            );
            benchkit::report_speedup("", &s_direct, &s);
        }

        // Quantized Legendre layer (Fig. 2 casts on the hot path).
        let mut qlayer = WinoConv2d::new(4, &w, Base::Legendre);
        qlayer.quantize(QuantConfig::w8(), &x, 1);
        let tiles = qlayer.engine().tile_count_for(&x.dims, cfg.padding) as f64;
        let mut scratch = EngineScratch::new();
        let s_q = benchkit::bench(2, 8, || qlayer.forward_with_scratch(&x, cfg, &mut scratch));
        benchkit::report(
            &format!("wino F4 legendre int8 C={c} {hw}x{hw}"),
            &s_q,
            Some((tiles, "tiles")),
        );
        println!();
    }
}

/// Engine acceptance shape: C=K=64, 32×32, batch 8 — batched engine vs
/// the per-tile reference path (the seed implementation).
fn engine_vs_per_tile(rng: &mut Prng) {
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let x = rand_tensor(rng, &[8, 64, 32, 32], 1.0);
    let w = rand_tensor(rng, &[64, 64, 3, 3], 0.2);
    let layer = WinoConv2d::new(4, &w, Base::Legendre);
    let tiles = layer.engine().tile_count_for(&x.dims, cfg.padding) as f64;

    println!("── engine acceptance shape: C=K=64 32x32 batch=8 ({tiles} tiles) ──");
    let s_ref = benchkit::bench(1, 5, || layer.forward_reference(&x, cfg));
    benchkit::report("per-tile reference (seed path)", &s_ref, Some((tiles, "tiles")));

    let mut scratch = EngineScratch::new();
    let s_eng = benchkit::bench(1, 5, || layer.forward_with_scratch(&x, cfg, &mut scratch));
    benchkit::report("batched engine (flat buffers)", &s_eng, Some((tiles, "tiles")));
    benchkit::report_speedup("engine vs per-tile", &s_ref, &s_eng);

    let ok = benchkit::speedup(&s_ref, &s_eng) >= 3.0;
    println!(
        "acceptance (engine ≥ 3x per-tile): {}",
        if ok { "PASS" } else { "FAIL" }
    );

    // Sanity on the measured run: both paths agree bit-for-bit.
    let yr = layer.forward_reference(&x, cfg);
    let ye = layer.forward_with_scratch(&x, cfg, &mut scratch);
    assert_eq!(yr.data, ye.data, "engine/per-tile outputs diverged");
    println!();
}

/// Integer engine vs the dequantize-to-float path on the acceptance
/// shape, emitting `BENCH_int.json` (path override: `WINOQ_BENCH_INT`).
/// Acceptance bar: the integer path delivers ≥ 2× tiles/sec.
fn int_vs_dequantize_float(rng: &mut Prng) {
    let cfg = Conv2dCfg { stride: 1, padding: 1 };
    let x = rand_tensor(rng, &[8, 64, 32, 32], 1.0);
    let w = rand_tensor(rng, &[64, 64, 3, 3], 0.2);
    let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
    layer.quantize(QuantConfig::w8_h9(), &x, 1);
    println!("── integer engine vs dequantize-to-float: w8_h9, C=K=64 32x32 batch=8 ──");
    let (json, ratio) = int_vs_float_bench_json(&layer, &x, cfg, 1, 5);
    println!("{json}");
    println!(
        "acceptance (int ≥ 2x float tiles/s): {} ({ratio:.2}x)",
        if ratio >= 2.0 { "PASS" } else { "FAIL" }
    );
    let path =
        std::env::var("WINOQ_BENCH_INT").unwrap_or_else(|_| "BENCH_int.json".to_string());
    match std::fs::write(&path, json + "\n") {
        Ok(()) => println!("BENCH_int.json written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    println!();
}

fn main() {
    let mut rng = Prng::new(9);
    engine_vs_per_tile(&mut rng);
    int_vs_dequantize_float(&mut rng);
    stage_shapes(&mut rng);
    println!("note: the arithmetic-count advantage is 9/2.25 = 4.0x; the measured");
    println!("ratio reflects this CPU's memory behaviour and the naive direct loop.");
    println!(
        "threads: {} (override with WINOQ_THREADS)",
        winoq::engine::parallel::num_threads()
    );
}
