//! Bench M2 (docs/ARCHITECTURE.md §Experiments): operation counts — general multiplications per
//! output point and pre/post-transform multiply-adds, canonical vs
//! Legendre, vs the Meng & Brothers superlinear variant the paper's §2
//! compares against.
//!
//! Run: `cargo bench --bench transform_cost`

use winoq::benchkit;
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::error::Prng;
use winoq::wino::toomcook::WinogradPlan;
use winoq::wino::transform::WinoF;

fn main() {
    println!("== M2a: general multiplications per 2-D output point ==");
    println!("{:>14} {:>10}", "method", "mults/pt");
    println!("{:>14} {:>10.2}", "direct 3x3", 9.0);
    for m in [2usize, 4, 6] {
        let plan = WinogradPlan::new(m, 3);
        println!(
            "{:>14} {:>10.2}",
            format!("F({m}x{m},3x3)"),
            plan.mults_per_output_2d()
        );
    }
    // Meng & Brothers use polynomials x, x±1, x²+1: a 7-point F(4,3)-class
    // scheme ⇒ 49/16 = 3.06 mults/output (paper §2). The Legendre method
    // keeps the optimal 36/16 = 2.25.
    println!("{:>14} {:>10.2}  (superlinear x²+1 scheme, paper ref [7])", "Meng&Brothers", 49.0 / 16.0);
    println!("{:>14} {:>10.2}  (this paper: base change keeps optimality)", "L-F(4x4)", 2.25);

    println!("\n== M2b: transform multiply-adds per tile (sparsity-priced) ==");
    println!(
        "{:>8} {:>6} | {:>10} {:>10} {:>10} | {:>12}",
        "tile", "base", "input", "output", "weight", "P overhead"
    );
    for m in [2usize, 4, 6] {
        let plan = WinogradPlan::new(m, 3);
        let cost = plan.cost_canonical();
        for base in [Base::Canonical, Base::Legendre] {
            let bc = BaseChange::new(base, plan.n);
            // The base change adds two sparse P-multiplications on each
            // two-sided transform: 2 * nnz(P) * N madds per conjugation.
            let p_madds = if bc.is_identity() {
                0
            } else {
                2 * bc.p.nnz() * plan.n
            };
            println!(
                "{:>8} {:>6} | {:>10} {:>10} {:>10} | {:>12}",
                format!("F({m},3)"),
                base.name(),
                cost.input_transform_madds + p_madds,
                cost.output_transform_madds + p_madds,
                cost.weight_transform_madds + p_madds,
                p_madds
            );
        }
    }
    println!("(paper §4.1: P is sparse — 6 nnz at 4x4, 12 at 6x6 — so the");
    println!(" extra pre/post work is marginal while Hadamard count is untouched)");

    println!("\n== M2c: measured wall-clock of the tile transforms (f64) ==");
    let mut rng = Prng::new(5);
    for m in [2usize, 4, 6] {
        let plan = WinogradPlan::new(m, 3);
        let x = rng.mat(plan.n, plan.n, 1.0);
        let w = rng.mat(3, 3, 0.5);
        for base in [Base::Canonical, Base::Legendre] {
            let wf = WinoF::new(&plan, base);
            let s = benchkit::bench(50, 300, || wf.correlate_tile(&x, &w));
            benchkit::report(
                &format!("tile F({m},3) {} full pipeline", base.name()),
                &s,
                Some(((m * m) as f64, "out-px")),
            );
        }
    }
}
