//! Vendored, API-compatible subset of `anyhow` (dtolnay/anyhow).
//!
//! This build environment has no crates.io access, so the handful of
//! `anyhow` features the crate uses — [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`] macros and the [`Context`] extension trait — are
//! reimplemented here as a path dependency. The surface is intentionally
//! tiny; if the real crate ever becomes available this directory can be
//! deleted and the `Cargo.toml` entry pointed at crates.io unchanged.

use std::fmt;

/// A string-backed error with an optional chain of context frames.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement [`std::error::Error`]: that is what allows the blanket
/// `From<E: std::error::Error>` conversion below to coexist with the
/// standard library's reflexive `From<T> for T`.
pub struct Error {
    /// Outermost message first (most recent context frame at index 0).
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole cause chain, like the real anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::msg(err)
    }
}

/// `anyhow::Result<T>` — a [`Result`](std::result::Result) defaulting its
/// error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an ad-hoc [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an ad-hoc [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok() -> Result<u32> {
        let v: u32 = "42".parse().context("parsing")?;
        Ok(v)
    }

    fn parse_err() -> Result<u32> {
        let v: u32 = "nope".parse().with_context(|| format!("parsing {:?}", "nope"))?;
        Ok(v)
    }

    fn bails(flag: bool) -> Result<()> {
        if flag {
            bail!("flag was {flag}");
        }
        Ok(())
    }

    #[test]
    fn ok_path() {
        assert_eq!(parse_ok().unwrap(), 42);
        assert!(bails(false).is_ok());
    }

    #[test]
    fn error_carries_context() {
        let e = parse_err().unwrap_err();
        assert!(e.to_string().contains("parsing"));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "missing cause chain: {dbg}");
    }

    #[test]
    fn bail_formats() {
        let e = bails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn io_error_converts() {
        let r: Result<Vec<u8>> =
            std::fs::read("/definitely/not/a/path").map_err(Into::into);
        assert!(r.is_err());
    }

    #[test]
    fn question_mark_on_anyhow_error() {
        fn inner() -> Result<()> {
            bail!("inner failure")
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert!(outer().is_err());
    }
}
