//! Quantized int8 Winograd inference — the deployed pipeline of the
//! paper's Fig. 2, staged explicitly, plus the true-integer vs
//! fake-quant agreement check and a full-network serving demo.
//!
//! Run: `cargo run --release --example quantized_inference`

use winoq::data::synthcifar;
use winoq::nn::{ConvMode, ResNet18, ResNetCfg};
use winoq::quant::{QWino, QuantConfig};
use winoq::wino::basis::Base;
use winoq::wino::conv::direct_correlate_2d;
use winoq::wino::error::Prng;

fn main() {
    // --- Stage-by-stage Fig. 2 walk on one tile -------------------------
    let qw = QWino::new_quantized_mats(4, 3, Base::Legendre, QuantConfig::w8(), 8);
    let mut rng = Prng::new(11);
    let cal_x: Vec<_> = (0..32).map(|_| rng.mat(6, 6, 1.0)).collect();
    let cal_w: Vec<_> = (0..32).map(|_| rng.mat(3, 3, 0.5)).collect();
    let scales = qw.calibrate(&cal_x, &cal_w);
    println!("calibrated stage scales (Fig. 2 cast sites):");
    println!("  input      : {:>9.6} ({} bits)", scales.input.scale, scales.input.bits);
    println!("  weights    : {:>9.6} ({} bits)", scales.weights.scale, scales.weights.bits);
    println!("  input_t    : {:>9.6} ({} bits)", scales.input_t.scale, scales.input_t.bits);
    println!("  weights_t  : {:>9.6} ({} bits)", scales.weights_t.scale, scales.weights_t.bits);
    println!("  hadamard   : {:>9.6} ({} bits)", scales.hadamard.scale, scales.hadamard.bits);
    println!("  output     : {:>9.6} ({} bits)", scales.output.scale, scales.output.bits);

    let x = rng.mat(6, 6, 1.0);
    let w = rng.mat(3, 3, 0.5);
    let oracle = direct_correlate_2d(&x, &w);
    let y_fake = qw.forward_fake(&x, &w, &scales);
    let y_int = qw.forward_int(&x, &w, &scales);
    println!("\none tile, F(4x4,3x3), Legendre base:");
    println!("oracle row 0      : {:?}", &oracle.data()[..4]);
    println!("fake-quant row 0  : {:?}", &y_fake.data()[..4]);
    println!("true-int8 row 0   : {:?}", &y_int.data()[..4]);
    let mut max_d = 0f64;
    for (a, b) in y_fake.data().iter().zip(y_int.data()) {
        max_d = max_d.max((a - b).abs());
    }
    println!(
        "fake vs int max |Δ| = {max_d:.6} (≤ one output quant step {:.6})",
        scales.output.scale
    );

    // --- Whole-network int8 serving demo --------------------------------
    println!("\nResNet18x0.25 serving with int8 L-Winograd layers:");
    let cfg = ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd {
            m: 4,
            base: Base::Legendre,
            quant: Some(QuantConfig::w8()),
        },
    };
    let mut net = ResNet18::init(cfg, 3);
    let (calib, _) = synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, 8);
    net.calibrate_quant(&calib);
    let (images, labels) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, 32);
    let t = std::time::Instant::now();
    let logits = net.forward(&images);
    let dt = t.elapsed().as_secs_f64();
    let preds = logits.argmax_rows();
    let correct = preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
    println!(
        "  {} images in {:.1} ms ({:.1} img/s), accuracy {:.1}% (untrained weights ⇒ ~chance)",
        labels.len(),
        dt * 1e3,
        labels.len() as f64 / dt,
        correct as f64 / labels.len() as f64 * 100.0
    );
    println!("  (train first with examples/train_synth_cifar for a real checkpoint)");
}
