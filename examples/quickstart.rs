//! Quickstart: the paper's algorithm end to end on one tile.
//!
//! Walks the Fig. 1 dataflow (and its Fig. 2 quantized variant) stage by
//! stage: exact matrix construction, Legendre base change, float pipeline
//! vs the direct oracle, then the 8-bit / 8+9-bit quantized pipelines.
//!
//! Run: `cargo run --release --example quickstart`

use winoq::quant::{QWino, QuantConfig};
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::conv::direct_correlate_2d;
use winoq::wino::error::Prng;
use winoq::wino::toomcook::WinogradPlan;
use winoq::wino::transform::WinoF;

fn main() {
    // 1. Construct F(4x4, 3x3) exactly: A (6x4), G (6x3), B^T (6x6).
    let plan = WinogradPlan::new(4, 3);
    println!("F(4x4, 3x3): N = {}, points = {:?}", plan.n, plan.points);
    println!(
        "general multiplications per output: {:.2} (direct needs {})",
        plan.mults_per_output_2d(),
        9
    );
    println!("\nG (weight transform):\n{:?}", plan.g);
    println!("Bᵀ (input transform):\n{:?}", plan.bt);

    // 2. The paper's base change: normalised Legendre polynomials.
    let bc = BaseChange::new(Base::Legendre, plan.n);
    println!("Legendre base-change Pᵀ (paper §4.1):\n{:?}", bc.p.transpose());
    println!(
        "P is sparse: {} non-zeros of {} ({} off-diagonal)",
        bc.p.nnz(),
        plan.n * plan.n,
        bc.nnz_offdiag()
    );

    // 3. One tile through the float pipeline, both bases, vs direct oracle.
    let mut rng = Prng::new(42);
    let x = rng.mat(6, 6, 1.0);
    let w = rng.mat(3, 3, 0.5);
    let oracle = direct_correlate_2d(&x, &w);
    println!("\ndirect convolution oracle:\n{oracle:?}");
    for base in [Base::Canonical, Base::Legendre] {
        let wf = WinoF::new(&plan, base);
        let y = wf.correlate_tile(&x, &w);
        let mut max_err = 0f64;
        for i in 0..4 {
            for j in 0..4 {
                max_err = max_err.max((y[(i, j)] - oracle[(i, j)]).abs());
            }
        }
        println!("{:<10} winograd max |err| vs oracle: {max_err:.2e}", base.name());
    }

    // 4. The quantized pipeline (Fig. 2): 8-bit vs 8-bit + 9-bit Hadamard.
    println!("\nquantized pipeline, mean relative L2 error over 300 tiles:");
    println!("{:>12} {:>12} {:>12}", "config", "canonical", "legendre");
    for (label, cfg) in [("8 bits", QuantConfig::w8()), ("8b + 9b", QuantConfig::w8_h9())] {
        let e_can = QWino::new_quantized_mats(4, 3, Base::Canonical, cfg, 8)
            .measure_error(300, 7);
        let e_leg = QWino::new_quantized_mats(4, 3, Base::Legendre, cfg, 8)
            .measure_error(300, 7);
        println!("{label:>12} {e_can:>12.4} {e_leg:>12.4}");
    }
    println!(
        "\n→ the Legendre base cuts the quantized error while keeping the \
         2.25 mults/output optimal (paper §4–§5)."
    );
}
