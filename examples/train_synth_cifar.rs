//! END-TO-END DRIVER: train ResNet18 through the full three-layer stack.
//!
//! Exercises every layer at once: the rust coordinator (L3) streams
//! synthetic-CIFAR batches into the AOT-compiled JAX train step (L2, with
//! the quantized Winograd layers whose tile pipeline is the Pallas kernel's
//! math, L1), evaluates on the held-out split, logs the loss curve, and
//! writes a checkpoint + metrics CSV (the historical end-to-end validation
//! run for the reproduction came from this binary).
//!
//! Run: `make artifacts && cargo run --release --example train_synth_cifar
//!       [tag] [steps]`  (default: t2-L-flex-8b-w0.25, 300 steps)

use std::path::PathBuf;
use winoq::coordinator::schedule::Schedule;
use winoq::coordinator::trainer::{self, TrainCfg};
use winoq::runtime::Artifact;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tag = args.first().map(|s| s.as_str()).unwrap_or("t2-L-flex-8b-w0.25");
    let steps: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let dir = winoq::runtime::artifacts_dir();

    eprintln!("== winoq end-to-end training driver ==");
    eprintln!("artifact: {tag}  steps: {steps}");
    eprintln!("compiling HLO on the PJRT CPU client…");
    let t0 = std::time::Instant::now();
    let artifact = Artifact::load(&dir, tag)?;
    eprintln!(
        "compiled in {:.1}s; {} params ({} f32 values)",
        t0.elapsed().as_secs_f64(),
        artifact.manifest.params.len(),
        artifact.manifest.total_param_len()
    );

    let cfg = TrainCfg {
        steps,
        schedule: Schedule::WarmupCosine {
            lr: 0.08,
            warmup: steps / 10,
            total: steps,
            final_frac: 0.02,
        },
        eval_every: (steps / 6).max(1),
        eval_batches: 5,
        log_every: 10,
        checkpoint: Some(PathBuf::from(format!("out/{tag}.ckpt.bin"))),
        dataset_size: 4096,
    };
    let t1 = std::time::Instant::now();
    let outcome = trainer::train(&artifact, &dir, &cfg)?;
    let train_s = t1.elapsed().as_secs_f64();

    let csv = PathBuf::from(format!("out/{tag}.metrics.csv"));
    outcome.log.write_csv(&csv)?;

    println!("\n== loss curve (train, every ~{} steps) ==", (steps / 12).max(1));
    let stride = (outcome.log.records.len() / 12).max(1);
    for rec in outcome.log.records.iter().step_by(stride) {
        println!(
            "  step {:>5}  loss {:>7.4}  acc {:>5.3}  lr {:.4}",
            rec.step, rec.loss, rec.acc, rec.lr
        );
    }
    println!("\n== eval curve ==");
    for &(step, loss, acc) in &outcome.log.evals {
        println!("  step {step:>5}  eval loss {loss:>7.4}  eval acc {:>6.2}%", acc * 100.0);
    }
    println!(
        "\nfinal eval accuracy: {:.2}%  (loss {:.4})",
        outcome.final_eval_acc * 100.0,
        outcome.final_eval_loss
    );
    println!(
        "wall: {train_s:.1}s for {steps} steps = {:.0} ms/step (batch {})",
        train_s / steps as f64 * 1e3,
        artifact.manifest.train_batch
    );
    println!("checkpoint: out/{tag}.ckpt.bin   metrics: {}", csv.display());
    Ok(())
}
