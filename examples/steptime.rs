use std::path::Path;
use winoq::data::synthcifar;
use winoq::runtime::Artifact;
fn main() {
    for tag in ["t2-L-flex-8b-w0.25", "t1-L-flex-8b-w0.5"] {
        let dir = Path::new("artifacts");
        let t0 = std::time::Instant::now();
        let art = Artifact::load(dir, tag).unwrap();
        let compile_s = t0.elapsed().as_secs_f64();
        let mut state = art.init_state(dir).unwrap();
        let m = &art.manifest;
        let (imgs, labels) = synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, m.train_batch);
        let l: Vec<i32> = labels.iter().map(|&x| x as i32).collect();
        art.train_step(&mut state, &imgs.data, &l, 0.05).unwrap();
        let t1 = std::time::Instant::now();
        for _ in 0..5 { art.train_step(&mut state, &imgs.data, &l, 0.05).unwrap(); }
        let step_s = t1.elapsed().as_secs_f64() / 5.0;
        let (eimgs, elabels) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, m.eval_batch);
        let el: Vec<i32> = elabels.iter().map(|&x| x as i32).collect();
        let t2 = std::time::Instant::now();
        art.eval_step(&state, &eimgs.data, &el).unwrap();
        println!("{tag}: compile {compile_s:.1}s, step {step_s:.3}s, eval {:.3}s", t2.elapsed().as_secs_f64());
    }
}
