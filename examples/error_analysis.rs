//! Numerical-error analysis across tile sizes, bases and bit widths —
//! regenerates the paper's motivating claims (§1: error grows with tile
//! size; §4.1: the Legendre base conditions the transforms).
//!
//! Run: `cargo run --release --example error_analysis`

use winoq::quant::{QWino, QuantConfig};
use winoq::wino::basis::Base;
use winoq::wino::error::{condition_numbers, measure_tile_error};

fn main() {
    let bases = [Base::Canonical, Base::Legendre, Base::Chebyshev];

    println!("== fp32 Winograd pipeline, mean relative L2 error vs f64 direct oracle ==");
    println!("{:>8} {:>13} {:>13} {:>13}", "tile", "canonical", "legendre", "chebyshev");
    for m in [2usize, 4, 6, 8] {
        print!("{:>8}", format!("F({m},3)"));
        for base in bases {
            let e = measure_tile_error(m, 3, base, 400, 42);
            print!(" {:>13.3e}", e.mean_rel_l2);
        }
        println!();
    }
    println!("(error grows steeply with tile size — the paper's §1 claim)");

    println!("\n== condition numbers κ₂ of the transform matrices ==");
    println!("{:>8} {:>22} {:>22}", "tile", "κ(Bᵀ) can → leg", "κ(G) can → leg");
    for m in [2usize, 4, 6, 8] {
        let c = condition_numbers(m, 3, Base::Canonical);
        let l = condition_numbers(m, 3, Base::Legendre);
        println!(
            "{:>8} {:>11.2} → {:<8.2} {:>11.2} → {:<8.2}",
            format!("F({m},3)"),
            c.kappa_bt,
            l.kappa_bt,
            c.kappa_g,
            l.kappa_g
        );
    }

    println!("\n== quantized pipeline (matrices + values quantized), rel L2 error ==");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12}",
        "tile", "bits", "canonical", "legendre", "chebyshev"
    );
    for m in [2usize, 4, 6] {
        for bits in [6u32, 8, 10, 12] {
            print!("{:>8} {:>6}", format!("F({m},3)"), bits);
            for base in bases {
                let q = QWino::new_quantized_mats(
                    m,
                    3,
                    base,
                    QuantConfig::uniform(bits),
                    bits,
                );
                print!(" {:>12.4}", q.measure_error(300, 17));
            }
            println!();
        }
    }

    println!("\n== the paper's Hadamard-bits knob at F(4,3) ==");
    println!("{:>10} {:>12} {:>12}", "config", "canonical", "legendre");
    for (label, cfg) in [
        ("8 bits", QuantConfig::w8()),
        ("8b + 9b", QuantConfig::w8_h9()),
        (
            "8b + 10b",
            QuantConfig { hadamard_bits: 10, ..QuantConfig::w8() },
        ),
    ] {
        print!("{label:>10}");
        for base in [Base::Canonical, Base::Legendre] {
            let q = QWino::new_quantized_mats(4, 3, base, cfg, 8);
            print!(" {:>12.4}", q.measure_error(400, 23));
        }
        println!();
    }
    println!("(widening only the Hadamard stage recovers most of the loss — §5/§6)");
}
