#!/usr/bin/env bash
# Lint + doc-rot gate for the winoq crate.
#
# Run from anywhere: resolves the repo root relative to this script.
# Fails fast on: formatting drift, clippy warnings, rustdoc warnings
# (broken intra-doc links are how stale docs die here), and doctest
# failures. Tier-1 correctness (`cargo build/test`) lives in ci.sh.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
cargo fmt --all --check

echo "==> cargo clippy (-D warnings)"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --document-private-items

echo "==> cargo test --doc"
cargo test --doc -q

echo "lint OK"
