#!/usr/bin/env bash
# Tier-1 verification + lint gate (see ROADMAP.md).
#
# Order matters: correctness first (build + all test targets including
# doctests), then the style/doc gate (scripts/lint.sh).

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "==> cargo build --release"
cargo build --release

# `cargo test` runs unit, integration AND doc tests; no separate
# --doc pass needed (lint.sh keeps one for standalone doc-gate runs).
echo "==> cargo test -q"
cargo test -q

"$SCRIPT_DIR/lint.sh"

echo "CI OK"
