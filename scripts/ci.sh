#!/usr/bin/env bash
# Tier-1 verification + lint gate (see ROADMAP.md).
#
# Order matters: correctness first (build + all test targets including
# doctests), then the style/doc gate (scripts/lint.sh).

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "==> cargo build --release"
cargo build --release

# `cargo test` runs unit, integration AND doc tests; no separate
# --doc pass needed (lint.sh keeps one for standalone doc-gate runs).
echo "==> cargo test -q"
cargo test -q

# Numeric-stack regression nets, run explicitly so a future test-filter
# change can never silently drop them: the rational-exact golden
# transform fixtures and the integer-engine-vs-scalar-oracle parity.
echo "==> golden transform vectors + int-vs-oracle parity"
cargo test -q --test golden_transforms --test int_parity

# GEMM kernel parity, both ways: once with runtime SIMD detection live
# (whatever this host supports — AVX2/NEON plus the opt-in FMA
# tolerance class) and once with the kill switch forcing the scalar
# kernels, so a parity break in either the SIMD kernels or the fallback
# dispatch can never hide behind the other configuration.
echo "==> gemm kernel parity suite (detected SIMD, then WINOQ_NO_SIMD=1)"
cargo test -q --test gemm_property
WINOQ_NO_SIMD=1 cargo test -q --test gemm_property

# Panel-GEMM bench: the register-tiled kernels must beat the naive
# stage-2 oracles on both the float and integer paths at the
# ResNet18-shaped layer, and the emitter itself asserts tiled/naive
# bit-parity on the measured buffers. The acceptance target is 1.5x;
# CI fails below 1.0 (a loaded runner gets slack, a regression to
# parity-or-worse does not). Seeds the bench trajectory BENCH_gemm.json.
echo "==> winoq bench (tiled vs naive panel GEMM) + BENCH_gemm.json"
GEMM_JSON="$SCRIPT_DIR/../BENCH_gemm.json"
./target/release/winoq bench --gemm-json "$GEMM_JSON"
if [ ! -s "$GEMM_JSON" ] || ! grep -q '"bench": "gemm"' "$GEMM_JSON"; then
  echo "gemm bench FAILED: BENCH_gemm.json missing or malformed" >&2
  exit 1
fi
# The detected-kernel line is mandatory: a bench artifact that cannot
# say which micro-kernels produced it is not comparable to anything.
KERNELS="$(sed -n 's/.*"kernel": {"float": "\([a-z0-9_]*\)", "int": "\([a-z0-9_]*\)".*/\1 \2/p' "$GEMM_JSON")"
if [ -z "$KERNELS" ]; then
  echo "gemm bench FAILED: BENCH_gemm.json lacks the detected-kernel line" >&2
  cat "$GEMM_JSON" >&2
  exit 1
fi
RATIOS="$(sed -n 's/.*"ratio_tiled_vs_naive": \([0-9.][0-9.]*\).*"ratio_tiled_vs_naive": \([0-9.][0-9.]*\).*/\1 \2/p' "$GEMM_JSON")"
if [ -z "$RATIOS" ]; then
  echo "gemm bench FAILED: BENCH_gemm.json has no float+int ratios" >&2
  cat "$GEMM_JSON" >&2
  exit 1
fi
if ! echo "$RATIOS" | awk '{ exit !($1 >= 1.0 && $2 >= 1.0) }'; then
  echo "gemm bench FAILED: tiled/naive ratio < 1 (float int: $RATIOS)" >&2
  cat "$GEMM_JSON" >&2
  exit 1
fi
echo "gemm bench OK (kernels: $KERNELS; float/int tiled-vs-naive ratios: $RATIOS)"

# Serve smoke: the micro-batching server must complete a synthetic
# closed-loop run and report non-zero completions in its stats JSON.
# Also refreshes the serve bench trajectory (BENCH_serve.json) and
# exercises the observability layer: the request trace must be
# well-formed JSON lines with exact span accounting, and the metrics
# snapshot must carry the registry's dotted names.
echo "==> winoq serve smoke (synthetic closed loop + trace + metrics)"
SMOKE_JSON="$(mktemp)"
TRACE_JSONL="$(mktemp)"
METRICS_JSONL="$(mktemp)"
./target/release/winoq serve --synthetic --requests 64 --max-batch 8 \
  --stats-json "$SMOKE_JSON" --bench-json "$SCRIPT_DIR/../BENCH_serve.json" \
  --trace-json "$TRACE_JSONL" --metrics-json "$METRICS_JSONL"
if [ ! -s "$SMOKE_JSON" ]; then
  echo "serve smoke FAILED: stats JSON missing or empty" >&2
  exit 1
fi
COMPLETED="$(sed -n 's/.*"completed": *\([0-9][0-9]*\).*/\1/p' "$SMOKE_JSON")"
if [ -z "$COMPLETED" ] || [ "$COMPLETED" -eq 0 ]; then
  echo "serve smoke FAILED: stats JSON reports zero completed requests" >&2
  cat "$SMOKE_JSON" >&2
  exit 1
fi
if ! grep -q '"stage_ns"' "$SMOKE_JSON" \
   || ! grep -q '"stage_ns_per_tile"' "$SMOKE_JSON"; then
  echo "serve smoke FAILED: stats JSON lacks the per-stage breakdown" >&2
  cat "$SMOKE_JSON" >&2
  exit 1
fi
if [ ! -s "$TRACE_JSONL" ] || grep -qv '^{.*}$' "$TRACE_JSONL"; then
  echo "serve smoke FAILED: trace output missing or not well-formed JSON lines" >&2
  exit 1
fi
SUBMITS="$(grep -c '"event": "submit"' "$TRACE_JSONL" || true)"
TERMINALS="$(grep -c '"event": "\(complete\|reject\|shed\|failed\)"' "$TRACE_JSONL" || true)"
COMPLETES="$(grep -c '"event": "complete"' "$TRACE_JSONL" || true)"
if [ "$COMPLETES" -ne 64 ] || [ "$SUBMITS" -lt 64 ] || [ "$SUBMITS" -ne "$TERMINALS" ]; then
  echo "serve smoke FAILED: trace span accounting is not exact" \
       "($SUBMITS submits, $TERMINALS terminals, $COMPLETES completes)" >&2
  exit 1
fi
if ! grep -q '"event": "stage"' "$TRACE_JSONL" \
   || ! grep -q '"event": "batch"' "$TRACE_JSONL"; then
  echo "serve smoke FAILED: trace lacks batch/stage events" >&2
  exit 1
fi
for metric in 'serve.requests.completed' 'serve.latency_us' \
              'engine.stage_ns.hadamard' 'plan_cache.plans.entries' \
              'serve.queue_depth.max'; do
  if ! grep -q "\"metric\": \"$metric\"" "$METRICS_JSONL"; then
    echo "serve smoke FAILED: metrics snapshot is missing $metric" >&2
    cat "$METRICS_JSONL" >&2
    exit 1
  fi
done
echo "serve smoke OK ($COMPLETED completed; $SUBMITS traced spans, $(wc -l < "$METRICS_JSONL") metrics)"
rm -f "$SMOKE_JSON" "$TRACE_JSONL" "$METRICS_JSONL"

# Drift smoke: shadow-oracle accuracy monitoring must stay quiet on
# calibrated traffic (the budgets are self-calibrated from the same
# distribution, so zero alerts) and must demonstrably fire on
# out-of-distribution traffic (inputs scaled 100x past the quantizers'
# calibrated ranges -> at least one alert). Both directions gated, so
# the alarm is proven live, not just silent.
echo "==> drift smoke (shadow oracle: calibrated quiet, OOD loud)"
DRIFT_CAL="$(mktemp)"
DRIFT_OOD="$(mktemp)"
./target/release/winoq serve --synthetic --requests 64 --max-batch 8 \
  --drift-json "$DRIFT_CAL" --drift-stride 4
CAL_COUNTS="$(sed -n 's/.*"sampled": \([0-9]*\), "alerts": \([0-9]*\).*/\1 \2/p' "$DRIFT_CAL")"
if [ -z "$CAL_COUNTS" ]; then
  echo "drift smoke FAILED: calibrated drift report missing sampled/alerts" >&2
  cat "$DRIFT_CAL" >&2
  exit 1
fi
if ! echo "$CAL_COUNTS" | awk '{ exit !($1 > 0 && $2 == 0) }'; then
  echo "drift smoke FAILED: calibrated traffic expected >0 sampled, 0 alerts (got: $CAL_COUNTS)" >&2
  cat "$DRIFT_CAL" >&2
  exit 1
fi
./target/release/winoq serve --synthetic --requests 64 --max-batch 8 \
  --drift-json "$DRIFT_OOD" --drift-stride 4 --input-scale 100
OOD_COUNTS="$(sed -n 's/.*"sampled": \([0-9]*\), "alerts": \([0-9]*\).*/\1 \2/p' "$DRIFT_OOD")"
if [ -z "$OOD_COUNTS" ] || ! echo "$OOD_COUNTS" | awk '{ exit !($1 > 0 && $2 >= 1) }'; then
  echo "drift smoke FAILED: 100x-scaled traffic raised no drift alert (got: $OOD_COUNTS)" >&2
  cat "$DRIFT_OOD" >&2
  exit 1
fi
if ! grep -q '"layer": ' "$DRIFT_OOD"; then
  echo "drift smoke FAILED: OOD drift report carries no per-layer entries" >&2
  cat "$DRIFT_OOD" >&2
  exit 1
fi
echo "drift smoke OK (calibrated: $CAL_COUNTS sampled/alerts; OOD x100: $OOD_COUNTS)"
rm -f "$DRIFT_CAL" "$DRIFT_OOD"

# Chaos smoke: injected worker panics must fail exactly their poisoned
# batches with a typed error while the supervisor restarts the worker
# inside its bounded budget — the run exits 0, accounting stays exact
# (completed + failed = requests), and the trace carries both the
# failed terminals and the span-0 worker_restart advisories. At
# max-batch 4 / 64 requests the schedule (seed 7, every 17th batch)
# deals 1–4 panics for any batch-assembly timing, always under the
# default restart budget of 5.
echo "==> chaos smoke (injected panics: typed failures + bounded restarts)"
CHAOS_STATS="$(mktemp)"
CHAOS_TRACE="$(mktemp)"
./target/release/winoq serve --synthetic --requests 64 --max-batch 4 \
  --chaos-panic-every 17 --chaos-seed 7 \
  --stats-json "$CHAOS_STATS" --trace-json "$CHAOS_TRACE"
CHAOS_ACCT="$(sed -n 's/.*"completed": *\([0-9]*\), "rejected": *\([0-9]*\), "shed": *\([0-9]*\), "failed": *\([0-9]*\).*/\1 \2 \3 \4/p' "$CHAOS_STATS" | head -n 1)"
if [ -z "$CHAOS_ACCT" ] || ! echo "$CHAOS_ACCT" | awk '{ exit !($1 + $4 == 64 && $4 >= 1) }'; then
  echo "chaos smoke FAILED: expected completed+failed=64 with >=1 failed (got: $CHAOS_ACCT)" >&2
  cat "$CHAOS_STATS" >&2
  exit 1
fi
CHAOS_RESTARTS="$(sed -n 's/.*"worker_restarts": *\([0-9][0-9]*\).*/\1/p' "$CHAOS_STATS" | head -n 1)"
if [ -z "$CHAOS_RESTARTS" ] || [ "$CHAOS_RESTARTS" -lt 1 ]; then
  echo "chaos smoke FAILED: no supervised worker restart recorded ($CHAOS_RESTARTS)" >&2
  cat "$CHAOS_STATS" >&2
  exit 1
fi
CHAOS_SUBMITS="$(grep -c '"event": "submit"' "$CHAOS_TRACE" || true)"
CHAOS_TERMINALS="$(grep -c '"event": "\(complete\|reject\|shed\|failed\)"' "$CHAOS_TRACE" || true)"
CHAOS_FAILED="$(grep -c '"event": "failed"' "$CHAOS_TRACE" || true)"
CHAOS_WR="$(grep -c '"event": "worker_restart"' "$CHAOS_TRACE" || true)"
if [ "$CHAOS_SUBMITS" -ne "$CHAOS_TERMINALS" ] || [ "$CHAOS_FAILED" -lt 1 ] || [ "$CHAOS_WR" -lt 1 ]; then
  echo "chaos smoke FAILED: trace not exact under chaos" \
       "($CHAOS_SUBMITS submits, $CHAOS_TERMINALS terminals, $CHAOS_FAILED failed, $CHAOS_WR restarts)" >&2
  exit 1
fi
echo "chaos smoke OK (accounting: $CHAOS_ACCT; $CHAOS_RESTARTS restart(s), $CHAOS_WR traced)"
rm -f "$CHAOS_STATS" "$CHAOS_TRACE"

# Fallback smoke: persistent drift must trip the per-layer circuit
# breaker. 100x-scaled traffic with a 1-alert trip threshold (and an
# unreachable quiet period, so the degradation is still visible at
# export) must engage at least one layer's fallback — observable as a
# fallback_engaged trace event AND a nonzero serve.degraded gauge —
# while the run still completes every request and exits 0.
echo "==> fallback smoke (drift-triggered engine degradation)"
FB_TRACE="$(mktemp)"
FB_METRICS="$(mktemp)"
FB_DRIFT="$(mktemp)"
./target/release/winoq serve --synthetic --requests 64 --max-batch 8 \
  --drift-json "$FB_DRIFT" --drift-stride 4 --input-scale 100 \
  --fallback-alerts 1 --fallback-quiet 100000 \
  --trace-json "$FB_TRACE" --metrics-json "$FB_METRICS"
if ! grep -q '"event": "fallback_engaged"' "$FB_TRACE"; then
  echo "fallback smoke FAILED: no fallback_engaged event on 100x OOD traffic" >&2
  exit 1
fi
DEGRADED="$(sed -n 's/.*"metric": "serve.degraded", "type": "gauge", "value": \([0-9.]*\).*/\1/p' "$FB_METRICS")"
if [ -z "$DEGRADED" ] || ! echo "$DEGRADED" | awk '{ exit !($1 > 0) }'; then
  echo "fallback smoke FAILED: serve.degraded gauge not raised (got: '$DEGRADED')" >&2
  cat "$FB_METRICS" >&2
  exit 1
fi
if ! grep -q '"metric": "pool.respawned"' "$FB_METRICS"; then
  echo "fallback smoke FAILED: metrics snapshot lacks the pool.respawned counter" >&2
  exit 1
fi
echo "fallback smoke OK (serve.degraded = $DEGRADED)"
rm -f "$FB_TRACE" "$FB_METRICS" "$FB_DRIFT"

# Integer-engine smoke: a 9-bit-Hadamard quantized serve run must
# complete (the quantized serving path is the integer engine) and the
# int-vs-float bench must emit a non-degenerate BENCH_int.json.
echo "==> winoq serve int-engine smoke (w8_h9) + BENCH_int.json"
INT_JSON="$SCRIPT_DIR/../BENCH_int.json"
./target/release/winoq serve --synthetic --quant w8_h9 --requests 32 \
  --max-batch 8 --int-bench-json "$INT_JSON"
if [ ! -s "$INT_JSON" ] || ! grep -q '"bench": "int_engine"' "$INT_JSON"; then
  echo "int smoke FAILED: BENCH_int.json missing or malformed" >&2
  exit 1
fi
if ! grep -q '"tiles_per_sec_ratio_int_vs_float"' "$INT_JSON" \
   || grep -q '"tiles_per_sec": 0\.0' "$INT_JSON"; then
  echo "int smoke FAILED: BENCH_int.json is degenerate" >&2
  cat "$INT_JSON" >&2
  exit 1
fi
echo "int smoke OK"

# Numeric-health gate: the saturation telemetry must demonstrably fire.
# Calibration-range input must show zero input-quantizer clips, the
# adversarial (2x calibration) input must clip, and the w8_h9 profile
# must show nonzero Hadamard-stage saturation on every case — the
# paper's extra Hadamard bit observable as a counter, not a claim.
echo "==> winoq bench --health-json (saturation counters) + BENCH_health.json"
HEALTH_JSON="$SCRIPT_DIR/../BENCH_health.json"
./target/release/winoq bench --health-json "$HEALTH_JSON"
if [ ! -s "$HEALTH_JSON" ] || ! grep -q '"bench": "numeric_health"' "$HEALTH_JSON"; then
  echo "health gate FAILED: BENCH_health.json missing or malformed" >&2
  exit 1
fi
HEALTH_CASES="$(sed 's/}, {/}\n{/g' "$HEALTH_JSON")"
if ! echo "$HEALTH_CASES" | grep -q '"quant": "w8"'; then
  echo "health gate FAILED: no w8 case in BENCH_health.json" >&2
  exit 1
fi
W8H9_SATS="$(echo "$HEALTH_CASES" | grep '"quant": "w8_h9"' \
  | sed -n 's/.*"adv_hadamard_sat": \([0-9][0-9]*\).*/\1/p')"
if [ -z "$W8H9_SATS" ] || echo "$W8H9_SATS" | awk '$1 == 0 { bad = 1 } END { exit !bad }'; then
  echo "health gate FAILED: w8_h9 shows no Hadamard saturation under adversarial input ($W8H9_SATS)" >&2
  cat "$HEALTH_JSON" >&2
  exit 1
fi
CALIB_CLIPS="$(echo "$HEALTH_CASES" | sed -n 's/.*"calib_input_sat": \([0-9][0-9]*\).*/\1/p')"
if echo "$CALIB_CLIPS" | awk '$1 != 0 { bad = 1 } END { exit !bad }'; then
  echo "health gate FAILED: calibration-range input clipped ($CALIB_CLIPS)" >&2
  exit 1
fi
echo "health gate OK (w8_h9 adversarial hadamard saturation: $(echo "$W8H9_SATS" | tr '\n' ' '))"

# Tune smoke: the autotuner must sweep a tiny grid (2 layers × 2
# candidates), emit a valid BENCH_tune.json + NetPlan, and the serve path
# must load that NetPlan and complete a closed-loop run.
echo "==> winoq tune smoke (tiny grid) + serve --plan"
TUNE_DIR="$(mktemp -d)"
./target/release/winoq tune --synthetic --grid tiny --layers 2 \
  --calib-batch 2 --plan-out "$TUNE_DIR/netplan.json" \
  --out "$SCRIPT_DIR/../BENCH_tune.json"
if [ ! -s "$SCRIPT_DIR/../BENCH_tune.json" ]; then
  echo "tune smoke FAILED: BENCH_tune.json missing or empty" >&2
  exit 1
fi
for key in '"bench": "tune"' '"winner"' '"endtoend"'; do
  if ! grep -q "$key" "$SCRIPT_DIR/../BENCH_tune.json"; then
    echo "tune smoke FAILED: BENCH_tune.json is missing $key" >&2
    exit 1
  fi
done
if [ ! -s "$TUNE_DIR/netplan.json" ] \
   || ! grep -q '"netplan_version": 2' "$TUNE_DIR/netplan.json"; then
  echo "tune smoke FAILED: NetPlan missing or not v2" >&2
  exit 1
fi
if ! grep -q '"tuned_err"' "$TUNE_DIR/netplan.json"; then
  echo "tune smoke FAILED: v2 NetPlan carries no tuned_err drift anchors" >&2
  cat "$TUNE_DIR/netplan.json" >&2
  exit 1
fi
PLAN_JSON="$(mktemp)"
./target/release/winoq serve --synthetic --plan "$TUNE_DIR/netplan.json" \
  --requests 32 --max-batch 4 --stats-json "$PLAN_JSON"
PLAN_COMPLETED="$(sed -n 's/.*"completed": *\([0-9][0-9]*\).*/\1/p' "$PLAN_JSON")"
if [ -z "$PLAN_COMPLETED" ] || [ "$PLAN_COMPLETED" -eq 0 ]; then
  echo "tune smoke FAILED: serve --plan completed zero requests" >&2
  cat "$PLAN_JSON" >&2
  exit 1
fi
if ! grep -q '"plan_cache"' "$PLAN_JSON"; then
  echo "tune smoke FAILED: stats JSON lacks plan_cache counters" >&2
  exit 1
fi
echo "tune smoke OK ($PLAN_COMPLETED requests served from the NetPlan)"
rm -f "$PLAN_JSON"
rm -rf "$TUNE_DIR"

# Soak smoke: the deterministic multi-model stress/soak simulation must
# complete, and its BENCH_serve_soak.json must be non-degenerate
# (p99.9 > 0), meet the SLO at the default operating point
# (deadline-miss-rate < 5%), and reconcile exactly
# (submitted = completed + rejected + shed).
echo "==> winoq serve --soak (multi-model deadline soak) + BENCH_serve_soak.json"
SOAK_JSON="$SCRIPT_DIR/../BENCH_serve_soak.json"
SOAK_TRACE="$(mktemp)"
./target/release/winoq serve --soak --requests 256 --models 2 \
  --deadline-us 20000 --soak-json "$SOAK_JSON" --trace-json "$SOAK_TRACE"
if [ ! -s "$SOAK_JSON" ] || ! grep -q '"bench": "serve_soak"' "$SOAK_JSON"; then
  echo "soak smoke FAILED: BENCH_serve_soak.json missing or malformed" >&2
  exit 1
fi
P999="$(sed -n 's/.*"p999": \([0-9.][0-9.]*\).*/\1/p' "$SOAK_JSON" | head -n 1)"
if [ -z "$P999" ] || ! echo "$P999" | awk '{ exit !($1 > 0) }'; then
  echo "soak smoke FAILED: degenerate p99.9 latency ($P999)" >&2
  cat "$SOAK_JSON" >&2
  exit 1
fi
MISS="$(sed -n 's/.*"deadline_miss_rate": \([0-9.][0-9.]*\).*/\1/p' "$SOAK_JSON")"
if [ -z "$MISS" ] || ! echo "$MISS" | awk '{ exit !($1 < 0.05) }'; then
  echo "soak smoke FAILED: deadline miss rate $MISS >= 5%" >&2
  cat "$SOAK_JSON" >&2
  exit 1
fi
TOTALS="$(sed -n 's/.*"totals": {"submitted": \([0-9]*\), "completed": \([0-9]*\), "rejected": \([0-9]*\), "shed": \([0-9]*\), "failed": \([0-9]*\).*/\1 \2 \3 \4 \5/p' "$SOAK_JSON")"
if [ -z "$TOTALS" ] || ! echo "$TOTALS" | awk '{ exit !($1 == $2 + $3 + $4 + $5 && $1 == 256) }'; then
  echo "soak smoke FAILED: totals do not reconcile ($TOTALS)" >&2
  cat "$SOAK_JSON" >&2
  exit 1
fi
echo "soak smoke OK (totals: $TOTALS, miss rate: $MISS, p99.9: ${P999}us)"

# Soak trace gate: the traced soak must emit well-formed JSON lines,
# account for every one of the 256 spans exactly (one submit, one
# terminal each), and — the determinism bar — replay byte-identically
# (trace AND report) when rerun with the same seed.
echo "==> soak trace gate (span accounting + per-seed byte-identity)"
if [ ! -s "$SOAK_TRACE" ] || grep -qv '^{.*}$' "$SOAK_TRACE"; then
  echo "soak trace FAILED: trace output missing or not well-formed JSON lines" >&2
  exit 1
fi
SOAK_SUBMITS="$(grep -c '"event": "submit"' "$SOAK_TRACE" || true)"
SOAK_TERMINALS="$(grep -c '"event": "\(complete\|reject\|shed\|failed\)"' "$SOAK_TRACE" || true)"
if [ "$SOAK_SUBMITS" -ne 256 ] || [ "$SOAK_TERMINALS" -ne 256 ]; then
  echo "soak trace FAILED: span accounting is not exact" \
       "($SOAK_SUBMITS submits, $SOAK_TERMINALS terminals, want 256 each)" >&2
  exit 1
fi
SOAK_JSON2="$(mktemp)"
SOAK_TRACE2="$(mktemp)"
./target/release/winoq serve --soak --requests 256 --models 2 \
  --deadline-us 20000 --soak-json "$SOAK_JSON2" --trace-json "$SOAK_TRACE2"
if ! cmp -s "$SOAK_TRACE" "$SOAK_TRACE2"; then
  echo "soak trace FAILED: same seed did not replay the trace byte-identically" >&2
  exit 1
fi
if ! cmp -s "$SOAK_JSON" "$SOAK_JSON2"; then
  echo "soak trace FAILED: same seed did not replay the report byte-identically" >&2
  exit 1
fi
echo "soak trace OK ($SOAK_SUBMITS spans, $(wc -l < "$SOAK_TRACE") events, byte-identical rerun)"
rm -f "$SOAK_TRACE" "$SOAK_JSON2" "$SOAK_TRACE2"

# Bench regression gate: every BENCH_*.json this run produced is diffed
# against the committed baselines in bench/baselines/ — throughput
# regressions beyond 10% or ANY error-metric increase fail the build.
# First run on a fresh checkout bootstraps the baselines from the
# current run's artifacts (commit them to arm the gate).
echo "==> winoq benchdiff (BENCH_*.json vs bench/baselines/)"
BASELINES="$SCRIPT_DIR/../bench/baselines"
if ! ls "$BASELINES"/BENCH_*.json > /dev/null 2>&1; then
  mkdir -p "$BASELINES"
  cp "$SCRIPT_DIR"/../BENCH_*.json "$BASELINES"/
  rm -f "$BASELINES/BENCH_diff.json" # the diff report is not itself a baseline
  echo "benchdiff: no committed baselines yet; bootstrapped $(ls "$BASELINES" | wc -l)" \
       "artifact(s) into bench/baselines/ — commit them to arm the gate"
else
  ./target/release/winoq benchdiff --baseline "$BASELINES" \
    --current "$SCRIPT_DIR/.." --out "$SCRIPT_DIR/../BENCH_diff.json"
fi

# Scale-out serving regression nets, run explicitly like the numeric
# ones: the deadline-scheduler property suite, the arbitrary-H×W parity
# suite, the multi-shard stress tests, and the self-healing chaos suite
# (fault injection, bounded restarts, drift-triggered fallback).
echo "==> serve_deadline + shape_parity + serve_stress + serve_chaos"
cargo test -q --test serve_deadline --test shape_parity --test serve_stress \
  --test serve_chaos

"$SCRIPT_DIR/lint.sh"

echo "CI OK"
