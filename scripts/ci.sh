#!/usr/bin/env bash
# Tier-1 verification + lint gate (see ROADMAP.md).
#
# Order matters: correctness first (build + all test targets including
# doctests), then the style/doc gate (scripts/lint.sh).

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "==> cargo build --release"
cargo build --release

# `cargo test` runs unit, integration AND doc tests; no separate
# --doc pass needed (lint.sh keeps one for standalone doc-gate runs).
echo "==> cargo test -q"
cargo test -q

# Serve smoke: the micro-batching server must complete a synthetic
# closed-loop run and report non-zero completions in its stats JSON.
# Also refreshes the serve bench trajectory (BENCH_serve.json).
echo "==> winoq serve smoke (synthetic closed loop)"
SMOKE_JSON="$(mktemp)"
./target/release/winoq serve --synthetic --requests 64 --max-batch 8 \
  --stats-json "$SMOKE_JSON" --bench-json "$SCRIPT_DIR/../BENCH_serve.json"
if [ ! -s "$SMOKE_JSON" ]; then
  echo "serve smoke FAILED: stats JSON missing or empty" >&2
  exit 1
fi
COMPLETED="$(sed -n 's/.*"completed": *\([0-9][0-9]*\).*/\1/p' "$SMOKE_JSON")"
if [ -z "$COMPLETED" ] || [ "$COMPLETED" -eq 0 ]; then
  echo "serve smoke FAILED: stats JSON reports zero completed requests" >&2
  cat "$SMOKE_JSON" >&2
  exit 1
fi
echo "serve smoke OK ($COMPLETED requests completed)"
rm -f "$SMOKE_JSON"

"$SCRIPT_DIR/lint.sh"

echo "CI OK"
