"""L2 model tests: layer/oracle agreement, variant behaviour, train-step
mechanics — on a tiny width so the suite stays fast."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, model, resnet, wino
from compile.kernels import ref
from compile.layers import WinoSpec
from compile.resnet import ModelCfg

TINY = dict(width_mult=0.0625, num_classes=10)  # widths [4,8,16,32]


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------- wino_conv2d layer ----------


@pytest.mark.parametrize("base", ["canonical", "legendre"])
def test_float_wino_layer_matches_direct(base):
    mats = wino.winograd_matrices_np(4, 3, base)
    spec = WinoSpec(4, 3, base, False, None, None, None)
    x = _rand((2, 3, 16, 16), 1)
    w = _rand((4, 3, 3, 3), 2, 0.4)
    y = layers.wino_conv2d(x, w, mats, spec, padding=1)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


def test_quantized_wino_layer_differs_but_close():
    mats = wino.winograd_matrices_np(4, 3, "legendre")
    spec = WinoSpec(4, 3, "legendre", False, 8, 8, 8)
    x = _rand((1, 4, 16, 16), 3)
    w = _rand((4, 4, 3, 3), 4, 0.3)
    y = layers.wino_conv2d(x, w, mats, spec, padding=1)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=1)
    err = float(jnp.sqrt(jnp.mean((y - y_ref) ** 2)))
    sig = float(jnp.sqrt(jnp.mean(y_ref**2)))
    assert 0 < err < 0.6 * sig


def test_wino_layer_grads_flow_to_matrices():
    """Flex mode trains the transform matrices: gradients must be nonzero."""
    mats = {
        k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
        for k, v in wino.winograd_matrices_np(4, 3, "legendre").items()
    }
    spec = WinoSpec(4, 3, "legendre", True, 8, 8, 8)
    x = _rand((1, 2, 8, 8), 5)
    w = _rand((2, 2, 3, 3), 6, 0.3)

    def loss(gp):
        m2 = dict(mats)
        m2["g_p"] = gp
        return jnp.sum(layers.wino_conv2d(x, w, m2, spec, padding=1) ** 2)

    g = jax.grad(loss)(mats["g_p"])
    assert float(jnp.max(jnp.abs(g))) > 0


# ---------- resnet ----------


def test_forward_shape_direct():
    cfg = ModelCfg(conv="direct", **TINY)
    params = resnet.init_params(cfg, seed=0)
    x = _rand((2, 3, 32, 32), 7)
    logits = resnet.forward(params, x, cfg)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_winograd_float_matches_direct_network():
    cfg_d = ModelCfg(conv="direct", **TINY)
    cfg_w = ModelCfg(conv="winograd", base="legendre", **TINY)
    params = resnet.init_params(cfg_d, seed=1)
    x = _rand((2, 3, 32, 32), 8)
    yd = resnet.forward(params, x, cfg_d)
    yw = resnet.forward(params, x, cfg_w)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yw), atol=5e-2)


def test_flex_params_added():
    cfg = ModelCfg(conv="winograd", base="legendre", flex=True, **TINY)
    params = resnet.init_params(cfg, seed=0)
    wino_names = [k for k in params if ".wino." in k]
    # stride-1 3x3 convs: stem + 16 block convs - 3 strided = 14, each with
    # 3 trainable matrices.
    assert len(wino_names) == 3 * len(resnet.wino_layer_names(cfg))
    assert len(resnet.wino_layer_names(cfg)) == 14


def test_param_names_sorted_and_stable():
    cfg = ModelCfg(conv="direct", **TINY)
    names = model.param_names(cfg)
    assert names == sorted(names)
    assert "fc.w" in names and "stem.w" in names


def test_conv_units_match_rust_structure():
    cfg = ModelCfg(conv="direct", **TINY)
    units = resnet.conv_units(cfg)
    assert len(units) == 20  # stem + 16 block convs + 3 downsamples
    downs = [u for u in units if u[0].endswith("down")]
    assert len(downs) == 3
    assert all(k == 1 for (_, _, _, _, k) in downs)


# ---------- train/eval steps ----------


def _setup_step(cfg, batch=4):
    params = resnet.init_params(cfg, seed=2)
    names = model.param_names(cfg)
    plist = [jnp.asarray(params[n]) for n in names]
    mlist = [jnp.zeros_like(p) for p in plist]
    imgs = _rand((batch, 3, 32, 32), 9)
    labels = jnp.asarray(np.arange(batch) % 10, jnp.int32)
    return plist, mlist, imgs, labels


@pytest.mark.parametrize(
    "cfg",
    [
        ModelCfg(conv="direct", act_bits=8, **TINY),
        ModelCfg(
            conv="winograd",
            base="legendre",
            flex=True,
            act_bits=8,
            hadamard_bits=9,
            mat_bits=8,
            **TINY,
        ),
    ],
    ids=["direct8", "Lflex8h9"],
)
def test_train_step_descends_fixed_batch(cfg):
    plist, mlist, imgs, labels = _setup_step(cfg)
    step = jax.jit(model.make_train_step(cfg))
    out = step(plist, mlist, imgs, labels, jnp.float32(0.05))
    first = float(out[2])
    for _ in range(4):
        out = step(out[0], out[1], imgs, labels, jnp.float32(0.05))
    assert float(out[2]) < first, f"{float(out[2])} !< {first}"


def test_eval_step_counts_correct():
    cfg = ModelCfg(conv="direct", **TINY)
    plist, _, imgs, labels = _setup_step(cfg, batch=6)
    ev = jax.jit(model.make_eval_step(cfg))
    loss, correct = ev(plist, imgs, labels)
    assert 0 <= int(correct) <= 6
    assert float(loss) > 0


def test_momentum_changes_trajectory():
    cfg = ModelCfg(conv="direct", **TINY)
    plist, mlist, imgs, labels = _setup_step(cfg)
    s_mom = jax.jit(model.make_train_step(cfg, momentum=0.9))
    s_plain = jax.jit(model.make_train_step(cfg, momentum=0.0))
    a = s_mom(plist, mlist, imgs, labels, jnp.float32(0.1))
    a = s_mom(a[0], a[1], imgs, labels, jnp.float32(0.1))
    b = s_plain(plist, mlist, imgs, labels, jnp.float32(0.1))
    b = s_plain(b[0], b[1], imgs, labels, jnp.float32(0.1))
    diff = max(
        float(jnp.max(jnp.abs(x - y))) for x, y in zip(a[0], b[0])
    )
    assert diff > 1e-6


def test_weight_decay_applied_to_weights_only():
    cfg = ModelCfg(conv="direct", **TINY)
    names = model.param_names(cfg)
    plist, mlist, imgs, labels = _setup_step(cfg)
    wd = jax.jit(model.make_train_step(cfg, weight_decay=1.0))
    nowd = jax.jit(model.make_train_step(cfg, weight_decay=0.0))
    a = wd(plist, mlist, imgs, labels, jnp.float32(0.01))
    b = nowd(plist, mlist, imgs, labels, jnp.float32(0.01))
    for n, pa, pb in zip(names, a[0], b[0]):
        d = float(jnp.max(jnp.abs(pa - pb)))
        if n.endswith(".bn.beta"):
            assert d < 1e-9, f"decay leaked into {n}"
