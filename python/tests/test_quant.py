"""Quantization semantics: fake-quant forward values, STE gradients, and
agreement with the rust `Quantizer` (same scheme, same rounding)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


def test_qmax():
    assert quant.qmax(8) == 127
    assert quant.qmax(9) == 255
    with pytest.raises(AssertionError):
        quant.qmax(1)


def test_extremes_map_exactly():
    x = jnp.asarray([-3.0, 1.0, 2.5, 3.0])
    y = quant.fake_quant(x, 8)
    np.testing.assert_allclose(float(y[-1]), 3.0, atol=1e-7)
    np.testing.assert_allclose(float(y[0]), -3.0, atol=1e-7)


def test_zero_tensor():
    x = jnp.zeros(4)
    y = quant.fake_quant(x, 8)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(4))


@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from([4, 8, 9, 12]),
    seed=st.integers(0, 1000),
)
def test_error_bounded_by_half_step(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64).astype(np.float32) * 5)
    y = quant.fake_quant(x, bits)
    step = float(jnp.max(jnp.abs(x))) / quant.qmax(bits)
    assert float(jnp.max(jnp.abs(y - x))) <= step / 2 + 1e-6


def test_ste_gradient_is_identity():
    # d/dx sum(fake_quant(x)) == 1 everywhere in the unclipped region.
    x = jnp.asarray([0.1, -0.5, 0.9])
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, 8)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(3), atol=1e-6)


def test_nine_bits_shrinks_worst_case_step():
    # A single value can round better at 8 than 9 bits; the guarantee is on
    # the worst case: the 9-bit step (and thus max error) is ~half.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=512).astype(np.float32))
    e8 = float(jnp.max(jnp.abs(quant.fake_quant(x, 8) - x)))
    e9 = float(jnp.max(jnp.abs(quant.fake_quant(x, 9) - x)))
    assert e9 < e8
    assert 1.0 / quant.qmax(9) < 1.0 / quant.qmax(8)


def test_matches_rust_scheme():
    """Same algorithm as rust Quantizer::calibrate + quantize: scale =
    max|x|/qmax, round-to-nearest, clamp."""
    rng = np.random.default_rng(42)
    x = rng.normal(size=32).astype(np.float32) * 3
    codes, scale = quant.quantize_codes(jnp.asarray(x), 8)
    scale = float(scale)
    qmax = 127
    expected = np.clip(np.round(x / scale), -qmax, qmax).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(codes), expected)


def test_fake_quant_idempotent():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=16).astype(np.float32))
    y = quant.fake_quant(x, 8)
    y2 = quant.fake_quant(y, 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)
