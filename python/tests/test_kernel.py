"""L1 kernel correctness: the Pallas Winograd kernel against the pure-jnp
direct-conv oracle, hypothesis-swept over shapes, bases, and tile sizes.
This is the CORE correctness signal for the kernel."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import wino
from compile.kernels import ref, winograd_pallas as wp


def _mats(m, base):
    return wino.winograd_matrices_np(m, 3, base)


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("base", ["canonical", "legendre", "chebyshev"])
@pytest.mark.parametrize("m", [2, 4])
def test_kernel_matches_direct(base, m):
    x = _rand((2, 3, 16, 16), 1)
    w = _rand((4, 3, 3, 3), 2, 0.4)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=1)
    y = wp.winograd_conv_pallas(x, w, _mats(m, base), m=m, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 5),
    k=st.integers(1, 5),
    hw=st.sampled_from([8, 11, 12, 16, 19]),
    base=st.sampled_from(["canonical", "legendre"]),
)
def test_kernel_shape_sweep(n, c, k, hw, base):
    """Hypothesis sweep: arbitrary N/C/K and non-tile-aligned spatial sizes
    must all match the direct oracle."""
    x = _rand((n, c, hw, hw), n * 100 + c * 10 + k)
    w = _rand((k, c, 3, 3), hw, 0.4)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=1)
    y = wp.winograd_conv_pallas(x, w, _mats(4, base), m=4, padding=1)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4)


def test_kernel_no_padding():
    x = _rand((1, 2, 14, 14), 5)
    w = _rand((3, 2, 3, 3), 6, 0.4)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=0)
    y = wp.winograd_conv_pallas(x, w, _mats(4, "legendre"), m=4, padding=0)
    assert y.shape == (1, 3, 12, 12)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


def test_kernel_quantized_runs_and_is_close():
    x = _rand((1, 4, 16, 16), 7)
    w = _rand((4, 4, 3, 3), 8, 0.3)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=1)
    y8 = wp.winograd_conv_pallas(
        x, w, _mats(4, "legendre"), m=4, padding=1, hadamard_bits=8
    )
    y9 = wp.winograd_conv_pallas(
        x, w, _mats(4, "legendre"), m=4, padding=1, hadamard_bits=9
    )
    e8 = float(jnp.sqrt(jnp.mean((y8 - y_ref) ** 2)))
    e9 = float(jnp.sqrt(jnp.mean((y9 - y_ref) ** 2)))
    sig = float(jnp.sqrt(jnp.mean(y_ref**2)))
    assert e8 > 0, "quantization must perturb the output"
    assert e8 < 0.5 * sig, f"8-bit error too large: {e8} vs signal {sig}"
    assert e9 < e8, f"9-bit hadamard {e9} must beat 8-bit {e8}"


def test_kernel_single_tile():
    """Smallest case: one 6x6 tile producing one 4x4 output block."""
    x = _rand((1, 1, 6, 6), 11)
    w = _rand((1, 1, 3, 3), 12)
    y_ref = ref.direct_conv2d_nchw(x, w, padding=0)
    y = wp.winograd_conv_pallas(x, w, _mats(4, "legendre"), m=4, padding=0)
    assert y.shape == (1, 1, 4, 4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_tile_extract_scatter_roundtrip():
    """extract_tiles/scatter_tiles invert each other for m == n_t (non-
    overlapping case)."""
    x = _rand((2, 3, 12, 12), 13)
    tiles = ref.extract_tiles(x, 4, 4)
    assert tiles.shape == (2, 3, 3, 3, 4, 4)
    y = ref.scatter_tiles(tiles, 12, 12)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_winograd_tile_ref_matches_direct():
    mats = wino.winograd_matrices_np(4, 3, "legendre")
    x = np.random.default_rng(3).normal(size=(6, 6)).astype(np.float32)
    w = np.random.default_rng(4).normal(size=(3, 3)).astype(np.float32)
    y = ref.winograd_tile_ref(jnp.asarray(x), jnp.asarray(w), mats)
    y_ref = ref.direct_conv2d_nchw(
        jnp.asarray(x)[None, None], jnp.asarray(w)[None, None], padding=0
    )[0, 0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
