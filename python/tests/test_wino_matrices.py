"""Exactness and golden tests for the python matrix construction
(`compile/wino.py`) — mirrors the rust test suite so the two constructions
can never drift apart."""

from fractions import Fraction

import numpy as np
import pytest

from compile import wino


def direct_corr(g, d, m):
    return [sum(g[j] * d[t + j] for j in range(len(g))) for t in range(m)]


def wino_corr(a, g_mat, bt, gv, dv):
    n = len(bt)
    gt = [sum(Fraction(g_mat[i][j]) * gv[j] for j in range(len(gv))) for i in range(n)]
    dt = [sum(Fraction(bt[i][j]) * dv[j] for j in range(n)) for i in range(n)]
    had = [a_ * b_ for a_, b_ in zip(gt, dt)]
    m = len(a[0])
    return [sum(a[i][t] * had[i] for i in range(n)) for t in range(m)]


@pytest.mark.parametrize("m,r", [(2, 3), (4, 3), (6, 3), (2, 5)])
def test_exactness_against_direct(m, r):
    a, g, bt = wino.toom_cook_matrices(m, r)
    n = m + r - 1
    rng = np.random.default_rng(m * 100 + r)
    for _ in range(20):
        gv = [Fraction(int(x), 4) for x in rng.integers(-16, 17, r)]
        dv = [Fraction(int(x), 2) for x in rng.integers(-16, 17, n)]
        assert wino_corr(a, g, bt, gv, dv) == direct_corr(gv, dv, m)


def test_f43_shapes():
    a, g, bt = wino.toom_cook_matrices(4, 3)
    assert (len(a), len(a[0])) == (6, 4)
    assert (len(g), len(g[0])) == (6, 3)
    assert (len(bt), len(bt[0])) == (6, 6)


def test_legendre_monic_matches_paper():
    # Paper §4.1 P^T rows: monic Legendre coefficients.
    assert wino.legendre_monic(2) == [Fraction(-1, 3), 0, 1]
    assert wino.legendre_monic(3) == [0, Fraction(-3, 5), 0, 1]
    assert wino.legendre_monic(4) == [Fraction(3, 35), 0, Fraction(-6, 7), 0, 1]
    assert wino.legendre_monic(5) == [
        0,
        Fraction(5, 21),
        0,
        Fraction(-10, 9),
        0,
        1,
    ]


def test_paper_pt_6x6_golden():
    p, p_inv = wino.base_change("legendre", 6)
    pt = [list(row) for row in zip(*p)]
    expected = [
        [1, 0, 0, 0, 0, 0],
        [0, 1, 0, 0, 0, 0],
        [Fraction(-1, 3), 0, 1, 0, 0, 0],
        [0, Fraction(-3, 5), 0, 1, 0, 0],
        [Fraction(3, 35), 0, Fraction(-6, 7), 0, 1, 0],
        [0, Fraction(5, 21), 0, Fraction(-10, 9), 0, 1],
    ]
    assert pt == expected
    # P * P^-1 == I exactly.
    ident = wino._matmul(p, p_inv)
    assert ident == wino._identity(6)


def test_p_sparsity_counts_match_paper():
    # Paper: 4x4 and 6x6 P have 6 and 12 non-zeros.
    for n, nnz_expected in [(4, 6), (6, 12)]:
        p, _ = wino.base_change("legendre", n)
        nnz = sum(1 for row in p for v in row if v != 0)
        assert nnz == nnz_expected


def test_chebyshev_base():
    p, p_inv = wino.base_change("chebyshev", 4)
    # monic T2 = x^2 - 1/2 ; monic T3 = x^3 - 3/4 x.
    assert p[0][2] == Fraction(-1, 2)
    assert p[1][3] == Fraction(-3, 4)
    assert wino._matmul(p, p_inv) == wino._identity(4)


def test_unknown_base_raises():
    with pytest.raises(ValueError):
        wino.base_change("hermite", 4)


def test_mult_count_f43():
    # 36 Hadamard mults for 16 outputs = 2.25/output (paper §2).
    a, g, bt = wino.toom_cook_matrices(4, 3)
    assert len(bt) ** 2 / (len(a[0]) ** 2) == pytest.approx(2.25)


def test_np_lowering_matches_exact():
    mats = wino.winograd_matrices_np(4, 3, "legendre", dtype=np.float64)
    a, g, bt = wino.toom_cook_matrices(4, 3)
    p, p_inv = wino.base_change("legendre", 6)
    a_p = wino._matmul(p, a)
    assert np.allclose(mats["a_p"], wino.to_np(a_p, np.float64))
    # bt_p = B^T P^T.
    btp = wino._matmul(bt, wino._transpose(p))
    assert np.allclose(mats["bt_p"], wino.to_np(btp, np.float64))
    assert not mats["identity_base"]


def test_canonical_mats_are_plain():
    mats = wino.winograd_matrices_np(4, 3, "canonical")
    assert mats["identity_base"]
    assert np.allclose(mats["a_p"], mats["a"])
    assert np.allclose(mats["p_inv"], np.eye(6))


def test_eq4_reduces_to_eq3_in_float():
    """The base-changed pipeline (paper eq. 4) must be algebraically equal
    to the canonical algorithm (eq. 3) in exact arithmetic — check to f64
    precision on random tiles."""
    rng = np.random.default_rng(7)
    mats_l = wino.winograd_matrices_np(4, 3, "legendre", dtype=np.float64)
    mats_c = wino.winograd_matrices_np(4, 3, "canonical", dtype=np.float64)
    for _ in range(10):
        x = rng.normal(size=(6, 6))
        w = rng.normal(size=(3, 3))
        # canonical
        u_c = mats_c["g_p"] @ w @ mats_c["g_p"].T
        v_c = mats_c["bt_p"] @ x @ mats_c["bt_p"].T
        y_c = mats_c["a_p"].T @ (u_c * v_c) @ mats_c["a_p"]
        # legendre (eq. 4)
        u_l = mats_l["p_inv"] @ (mats_l["g_p"] @ w @ mats_l["g_p"].T) @ mats_l["p_inv_t"]
        v_l = mats_l["bt_p"] @ (mats_l["p_inv_t"] @ x @ mats_l["p_inv"]) @ mats_l["bt_p"].T
        y_l = mats_l["a_p"].T @ (mats_l["p_inv_t"] @ (u_l * v_l) @ mats_l["p_inv"]) @ mats_l["a_p"]
        assert np.allclose(y_c, y_l, atol=1e-9)
