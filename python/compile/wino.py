"""Winograd/Toom-Cook matrix construction (exact, `fractions.Fraction`).

Python mirror of `rust/src/wino/{toomcook,basis}.rs` — same derivation
(Toom-Cook evaluation/interpolation + Matrix Exchange; see the rust module
docs), same point ladder, same `F = diag(N_i)` rebalancing convention, and
the same normalised-Legendre base change. `python/tests/test_wino_matrices.py`
cross-checks this construction against golden values (including the paper's
printed 6x6 `P^T`), which in turn pin the rust side via its own golden tests.

Everything here is build-time only: these matrices are baked as constants
into the JAX model (L2) and the Pallas kernel (L1) before AOT lowering.
"""

from __future__ import annotations

from fractions import Fraction
from typing import Callable, Sequence

import numpy as np

INF = "inf"  # sentinel for the point at infinity

# Canonical point ladder: 0, 1, -1, 1/2, -1/2, 2, -2, ... then infinity.
_LADDER = [
    Fraction(0),
    Fraction(1),
    Fraction(-1),
    Fraction(1, 2),
    Fraction(-1, 2),
    Fraction(2),
    Fraction(-2),
    Fraction(1, 4),
    Fraction(-1, 4),
    Fraction(4),
    Fraction(-4),
    Fraction(3, 4),
    Fraction(-3, 4),
]


def standard_points(n: int) -> list:
    """`n` interpolation points: n-1 from the ladder plus infinity."""
    assert 1 <= n - 1 <= len(_LADDER), f"point ladder exhausted for n={n}"
    return list(_LADDER[: n - 1]) + [INF]


def _frac_mat(rows, cols, fill) -> list[list[Fraction]]:
    return [[fill(i, j) for j in range(cols)] for i in range(rows)]


def _matmul(a, b):
    n, k, m = len(a), len(b), len(b[0])
    assert len(a[0]) == k
    out = [[Fraction(0)] * m for _ in range(n)]
    for i in range(n):
        for kk in range(k):
            if a[i][kk] == 0:
                continue
            for j in range(m):
                out[i][j] += a[i][kk] * b[kk][j]
    return out


def _transpose(a):
    return [list(row) for row in zip(*a)]


def _identity(n):
    return [[Fraction(1 if i == j else 0) for j in range(n)] for i in range(n)]


def _inverse(a):
    """Exact Gauss-Jordan inverse over Fractions."""
    n = len(a)
    m = [row[:] for row in a]
    inv = _identity(n)
    for col in range(n):
        piv = next(r for r in range(col, n) if m[r][col] != 0)
        m[col], m[piv] = m[piv], m[col]
        inv[col], inv[piv] = inv[piv], inv[col]
        p = m[col][col]
        m[col] = [v / p for v in m[col]]
        inv[col] = [v / p for v in inv[col]]
        for r in range(n):
            if r == col or m[r][col] == 0:
                continue
            f = m[r][col]
            m[r] = [mv - f * cv for mv, cv in zip(m[r], m[col])]
            inv[r] = [iv - f * cv for iv, cv in zip(inv[r], inv[col])]
    return inv


def toom_cook_matrices(m: int, r: int, points: Sequence | None = None):
    """Exact (A, G, Bt) for F(m, r): A is Nxm, G is Nxr, Bt is NxN.

    Same construction as rust `WinogradPlan::with_points` — generalised
    Vandermonde V (infinity row = e_N), A = V_m, G = F^-1 V_r,
    Bt = F V^-T with F = diag(N_i) Lagrange denominators.
    """
    n = m + r - 1
    pts = list(points) if points is not None else standard_points(n)
    assert len(pts) == n
    finite = [p for p in pts if p != INF]
    assert len(set(finite)) == len(finite), "duplicate points"
    if INF in pts:
        assert pts[-1] == INF and pts.count(INF) == 1

    def vand_row(p, width):
        if p == INF:
            return [Fraction(0)] * (width - 1) + [Fraction(1)]
        return [p**j for j in range(width)]

    v = [vand_row(p, n) for p in pts]
    a = [vand_row(p, m) for p in pts]
    g = [vand_row(p, r) for p in pts]

    f = [Fraction(1)] * n
    for i, pi in enumerate(finite):
        prod = Fraction(1)
        for k, pk in enumerate(finite):
            if k != i:
                prod *= pi - pk
        f[i] = prod

    g = [[gv / f[i] for gv in row] for i, row in enumerate(g)]
    v_inv_t = _transpose(_inverse(v))
    bt = [[f[i] * v_inv_t[i][j] for j in range(n)] for i in range(n)]
    return a, g, bt


def legendre_monic(k: int) -> list[Fraction]:
    """Canonical coefficients (low→high) of the monic Legendre P_k."""
    p0 = [Fraction(1)]
    if k == 0:
        return p0
    p1 = [Fraction(0), Fraction(1)]
    for j in range(1, k):
        a = Fraction(2 * j + 1, j + 1)
        b = Fraction(j, j + 1)
        xp1 = [Fraction(0)] + p1  # x * p1
        nxt = [a * c for c in xp1]
        for idx, c in enumerate(p0):
            nxt[idx] -= b * c
        p0, p1 = p1, nxt
    lead = p1[-1]
    return [c / lead for c in p1]


def chebyshev_monic(k: int) -> list[Fraction]:
    """Canonical coefficients of the monic Chebyshev T_k."""
    t0 = [Fraction(1)]
    if k == 0:
        return t0
    t1 = [Fraction(0), Fraction(1)]
    for _ in range(1, k):
        xt1 = [Fraction(0)] + t1
        nxt = [2 * c for c in xt1]
        for idx, c in enumerate(t0):
            nxt[idx] -= c
        t0, t1 = t1, nxt
    lead = t1[-1]
    return [c / lead for c in t1]


def base_change(base: str, n: int):
    """(P, P^-1) exact for the given base name ('canonical'/'legendre'/
    'chebyshev'). Column i of P = canonical coefficients of base poly i."""
    if base == "canonical":
        p = _identity(n)
        return p, _identity(n)
    family: Callable[[int], list[Fraction]]
    if base == "legendre":
        family = legendre_monic
    elif base == "chebyshev":
        family = chebyshev_monic
    else:
        raise ValueError(f"unknown base {base!r}")
    p = [[Fraction(0)] * n for _ in range(n)]
    for k in range(n):
        coeffs = family(k)
        assert len(coeffs) == k + 1 and coeffs[-1] == 1
        for j, c in enumerate(coeffs):
            p[j][k] = c
    return p, _inverse(p)


def to_np(mat, dtype=np.float32) -> np.ndarray:
    """Lower an exact Fraction matrix to a numpy array."""
    return np.array([[float(v) for v in row] for row in mat], dtype=dtype)


def winograd_matrices_np(m: int, r: int, base: str, dtype=np.float32):
    """The float matrices of the paper's eq. 4, ready for the JAX model:

    returns dict with a_p (N,m), g_p (N,r), bt_p (N,N)  [= (P B)^T],
    p_inv (N,N), p_inv_t (N,N), plus the plain canonical a/g/bt.
    """
    a, g, bt = toom_cook_matrices(m, r)
    n = m + r - 1
    p, p_inv = base_change(base, n)
    a_p = _matmul(p, a)
    g_p = _matmul(p, g)
    bt_p = _matmul(bt, _transpose(p))  # (P B)^T = B^T P^T
    return {
        "a": to_np(a, dtype),
        "g": to_np(g, dtype),
        "bt": to_np(bt, dtype),
        "a_p": to_np(a_p, dtype),
        "g_p": to_np(g_p, dtype),
        "bt_p": to_np(bt_p, dtype),
        "p_inv": to_np(p_inv, dtype),
        "p_inv_t": to_np(_transpose(p_inv), dtype),
        "identity_base": base == "canonical",
    }
