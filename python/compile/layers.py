"""L2 layers: the winograd-aware quantized conv (vectorised, differentiable)
plus direct-conv/BN/linear building blocks for the ResNet.

The winograd layer implements the paper's eq. 4 staged pipeline with the
Fig. 2 quantization casts and — crucially — fake-quantization of the
*transform matrices themselves* (the deployed int8 representation, and the
site where the polynomial base matters; see `rust/src/quant/qwino.rs` docs
for the measured mechanism). In *flex* mode the matrices `G_P, B_P, A_P`
arrive as trainable parameters (the paper keeps `P, P^-1` fixed), so the
STE gradients let training adapt them to their own quantization noise.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import quant
from .kernels import ref


class WinoSpec(NamedTuple):
    """Static configuration of one winograd-aware conv layer."""

    m: int  # output tile size (paper: 4)
    r: int  # kernel size (paper: 3)
    base: str  # canonical | legendre | chebyshev
    flex: bool  # transform matrices trainable?
    act_bits: int | None  # None = float (no quantization)
    hadamard_bits: int | None
    mat_bits: int | None  # fake-quant of the transform matrices


def _fq(x, bits):
    return x if bits is None else quant.fake_quant(x, bits)


def wino_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mats: dict,
    spec: WinoSpec,
    padding: int = 1,
) -> jnp.ndarray:
    """Winograd-aware conv: x [N,C,H,W], w [K,C,r,r] -> [N,K,H',W'].

    `mats` holds arrays `a_p (N,m)`, `g_p (N,r)`, `bt_p (N,N)`, `p_inv`,
    `p_inv_t` — constants in static mode, parameters in flex mode.
    """
    nb, c, h, wd = x.shape
    k = w.shape[0]
    n_t = spec.m + spec.r - 1
    oh = h + 2 * padding - spec.r + 1
    ow = wd + 2 * padding - spec.r + 1
    th = -(-oh // spec.m)
    tw = -(-ow // spec.m)
    ph = (th - 1) * spec.m + n_t
    pw = (tw - 1) * spec.m + n_t

    ident = bool(mats["identity_base"])
    a_p = jnp.asarray(mats["a_p"], jnp.float32)
    g_p = jnp.asarray(mats["g_p"], jnp.float32)
    bt_p = jnp.asarray(mats["bt_p"], jnp.float32)
    p_inv = jnp.asarray(mats["p_inv"], jnp.float32)
    p_inv_t = jnp.asarray(mats["p_inv_t"], jnp.float32)
    if spec.mat_bits is not None:
        # The trainable/storable transforms run in integer arithmetic on the
        # deployed target: hold their entries at mat_bits (STE lets flex
        # training adapt). P / P^-1 stay *exact* — the paper keeps them
        # fixed, and its Fig. 2 places casts around the G/B/A transforms
        # only; quantizing the P conjugations adds casts the paper does not
        # have and (measured, EXPERIMENTS.md §T1) destabilises flex training.
        a_p = quant.fake_quant(a_p, spec.mat_bits)
        g_p = quant.fake_quant(g_p, spec.mat_bits)
        bt_p = quant.fake_quant(bt_p, spec.mat_bits)

    # ---- weights: P^-1 (G_P W G_P^T) P^-T (paper eq. 2), one cast after.
    w = _fq(w, spec.act_bits)
    u = jnp.einsum("ij,kcjl,ml->kcim", g_p, w, g_p)
    if not ident:
        u = jnp.einsum("ij,kcjq,ql->kcil", p_inv, u, p_inv_t)
    u = _fq(u, spec.act_bits)

    # ---- input tiles: B_P^T (P^-T X P^-1) B_P, one cast after.
    x = _fq(x, spec.act_bits)
    xp = jnp.pad(
        x,
        ((0, 0), (0, 0), (padding, ph - h - padding), (padding, pw - wd - padding)),
    )
    tiles = ref.extract_tiles(xp, n_t, spec.m)  # [N,C,TH,TW,n,n]
    if not ident:
        tiles = jnp.einsum("ij,ncabjq,ql->ncabil", p_inv_t, tiles, p_inv)
    xt = jnp.einsum("ij,ncabjq,lq->ncabil", bt_p, tiles, bt_p)
    xt = _fq(xt, spec.act_bits)

    # ---- Hadamard product, accumulated over input channels.
    acc = jnp.einsum("kcij,ncabij->nkabij", u, xt)
    acc = _fq(acc, spec.hadamard_bits)

    # ---- output: A_P^T (P^-T M P^-1) A_P, one cast after.
    if not ident:
        acc = jnp.einsum("ij,nkabjq,ql->nkabil", p_inv_t, acc, p_inv)
    y_tiles = jnp.einsum("ji,nkabjq,ql->nkabil", a_p, acc, a_p)
    y_tiles = _fq(y_tiles, spec.act_bits)
    return ref.scatter_tiles(y_tiles, oh, ow)


def direct_conv2d_q(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    stride: int = 1,
    padding: int = 0,
    act_bits: int | None = None,
) -> jnp.ndarray:
    """Quantized direct convolution (the paper's baseline): fake-quant on
    activations and weights, f32 accumulation."""
    x = _fq(x, act_bits)
    w = _fq(w, act_bits)
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def batchnorm(x: jnp.ndarray, gamma, beta, eps: float = 1e-5) -> jnp.ndarray:
    """Batch normalisation over (N,H,W) per channel, batch statistics.

    Training-mode statistics are used in both train and eval steps (the
    eval batches are large enough that this matches running-stat behaviour;
    noted as a simplification in DESIGN.md)."""
    mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
    xhat = (x - mean) / jnp.sqrt(var + eps)
    return xhat * gamma[None, :, None, None] + beta[None, :, None, None]


def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x @ w + b


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(2, 3))
