"""ResNet18 (CIFAR variant, width multiplier) with winograd-aware quantized
convolution layers — the paper's experimental model.

Functional JAX model over a flat `dict[str, array]` parameter tree, mirrored
by the rust inference model (`rust/src/nn/resnet.rs`) and consumed by the
rust training coordinator through the AOT'd train/eval steps.

Variant axes (paper Tables 1-2):
  conv      direct | winograd
  base      canonical | legendre (| chebyshev, ablation)
  flex      static (fixed transforms) | flex (trainable G_P/B_P/A_P)
  bits      float | 8-bit | 8-bit + 9-bit Hadamard
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import layers, wino
from .layers import WinoSpec


class ModelCfg(NamedTuple):
    width_mult: float = 0.25
    num_classes: int = 10
    conv: str = "direct"  # direct | winograd
    base: str = "canonical"
    flex: bool = False
    act_bits: int | None = None
    hadamard_bits: int | None = None
    mat_bits: int | None = None
    m: int = 4  # winograd output tile

    @property
    def spec(self) -> WinoSpec:
        return WinoSpec(
            m=self.m,
            r=3,
            base=self.base,
            flex=self.flex,
            act_bits=self.act_bits,
            hadamard_bits=self.hadamard_bits,
            mat_bits=self.mat_bits,
        )

    def widths(self):
        return [max(4, int(round(c * self.width_mult))) for c in (64, 128, 256, 512)]

    def label(self) -> str:
        if self.conv == "direct":
            tag = "direct"
        else:
            tag = ("L-" if self.base == "legendre" else "") + (
                "flex" if self.flex else "static"
            )
            if self.base == "chebyshev":
                tag = "C-" + ("flex" if self.flex else "static")
        bits = (
            "float"
            if self.act_bits is None
            else (
                f"{self.act_bits}b"
                + (
                    f"h{self.hadamard_bits}"
                    if self.hadamard_bits != self.act_bits
                    else ""
                )
            )
        )
        return f"{tag}-{bits}-w{self.width_mult}"


def conv_units(cfg: ModelCfg):
    """(prefix, stride, cin, cout, ksize) for every conv in the network —
    identical structure to rust `ResNet18::conv_units`."""
    w = cfg.widths()
    units = [("stem", 1, 3, w[0], 3)]
    cin = w[0]
    for si, cout in enumerate(w):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            units.append((f"s{si}b{bi}.conv1", stride, cin, cout, 3))
            units.append((f"s{si}b{bi}.conv2", 1, cout, cout, 3))
            if stride != 1 or cin != cout:
                units.append((f"s{si}b{bi}.down", stride, cin, cout, 1))
            cin = cout
    return units


def wino_layer_names(cfg: ModelCfg):
    """Prefixes of convs that run through the winograd layer: stride-1 3x3
    (strided convs and 1x1 downsamples stay direct, as in ref [5])."""
    return [
        p
        for (p, stride, _ci, _co, k) in conv_units(cfg)
        if stride == 1 and k == 3
    ]


def init_params(cfg: ModelCfg, seed: int = 0) -> dict:
    """He-init conv weights, unit BN, zero biases; flex adds per-layer
    copies of the transform matrices (initialised at their exact values —
    'we treat matrices G_P, A_P, B_P as trainable parameters')."""
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    for prefix, _stride, cin, cout, k in conv_units(cfg):
        fan_in = cin * k * k
        std = float(np.sqrt(2.0 / fan_in))
        params[f"{prefix}.w"] = rng.normal(0.0, std, (cout, cin, k, k)).astype(
            np.float32
        )
        params[f"{prefix}.bn.gamma"] = np.ones(cout, np.float32)
        params[f"{prefix}.bn.beta"] = np.zeros(cout, np.float32)
    w3 = cfg.widths()[3]
    params["fc.w"] = rng.normal(
        0.0, np.sqrt(1.0 / w3), (w3, cfg.num_classes)
    ).astype(np.float32)
    params["fc.b"] = np.zeros(cfg.num_classes, np.float32)

    if cfg.conv == "winograd" and cfg.flex:
        mats = wino.winograd_matrices_np(cfg.m, 3, cfg.base)
        for prefix in wino_layer_names(cfg):
            params[f"{prefix}.wino.a_p"] = mats["a_p"].copy()
            params[f"{prefix}.wino.g_p"] = mats["g_p"].copy()
            params[f"{prefix}.wino.bt_p"] = mats["bt_p"].copy()
    return params


def _layer_mats(cfg: ModelCfg, params: dict, prefix: str, const_mats: dict) -> dict:
    """Assemble the transform-matrix dict for one layer: constants in
    static mode, parameters (plus fixed P^-1) in flex mode."""
    if not cfg.flex:
        return const_mats
    return {
        "a_p": params[f"{prefix}.wino.a_p"],
        "g_p": params[f"{prefix}.wino.g_p"],
        "bt_p": params[f"{prefix}.wino.bt_p"],
        "p_inv": const_mats["p_inv"],
        "p_inv_t": const_mats["p_inv_t"],
        "identity_base": const_mats["identity_base"],
    }


def forward(params: dict, x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """Logits [N, num_classes] for images x [N,3,H,W]."""
    const_mats = (
        wino.winograd_matrices_np(cfg.m, 3, cfg.base)
        if cfg.conv == "winograd"
        else None
    )
    wino_set = set(wino_layer_names(cfg)) if cfg.conv == "winograd" else set()

    def conv_unit(h, prefix, stride, ksize):
        w = params[f"{prefix}.w"]
        pad = 1 if ksize == 3 else 0
        if prefix in wino_set:
            mats = _layer_mats(cfg, params, prefix, const_mats)
            y = layers.wino_conv2d(h, w, mats, cfg.spec, padding=pad)
        else:
            y = layers.direct_conv2d_q(
                h, w, stride=stride, padding=pad, act_bits=cfg.act_bits
            )
        return layers.batchnorm(
            y, params[f"{prefix}.bn.gamma"], params[f"{prefix}.bn.beta"]
        )

    h = jnp.maximum(conv_unit(x, "stem", 1, 3), 0.0)
    widths = cfg.widths()
    cin = widths[0]
    for si, cout in enumerate(widths):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            prefix = f"s{si}b{bi}"
            y1 = jnp.maximum(conv_unit(h, f"{prefix}.conv1", stride, 3), 0.0)
            y2 = conv_unit(y1, f"{prefix}.conv2", 1, 3)
            if stride != 1 or cin != cout:
                sc = conv_unit(h, f"{prefix}.down", stride, 1)
            else:
                sc = h
            h = jnp.maximum(y2 + sc, 0.0)
            cin = cout
    pooled = layers.global_avg_pool(h)
    return layers.linear(pooled, params["fc.w"], params["fc.b"])
