"""AOT lowering: JAX train/eval/predict steps -> HLO *text* artifacts.

Build-time entry point (`make artifacts`). Python never runs on the request
path: the rust coordinator loads these artifacts via the `xla` crate's
HLO-text parser and drives training/serving from there.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Per variant the outputs are:
  artifacts/<tag>.train.hlo.txt    train_step (params, mom, images, labels, lr)
  artifacts/<tag>.eval.hlo.txt     eval_step  (params, images, labels)
  artifacts/<tag>.manifest.txt     param names/shapes + batch geometry
  artifacts/<tag>.init.bin         initial params + zero momentum, flat f32 LE
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, resnet
from .resnet import ModelCfg

TRAIN_BATCH = 32
EVAL_BATCH = 100
IMAGE_SHAPE = (3, 32, 32)


def variant_grid() -> dict[str, ModelCfg]:
    """The experiment grid of DESIGN.md §6 (Tables 1 and 2)."""

    def wcfg(width, base, flex, hbits):
        return ModelCfg(
            width_mult=width,
            conv="winograd",
            base=base,
            flex=flex,
            act_bits=8,
            hadamard_bits=hbits,
            mat_bits=8,
        )

    grid: dict[str, ModelCfg] = {}
    # Table 1: width 0.5, 8-bit and 8-bit+9-bit-Hadamard.
    grid["t1-direct-8b-w0.5"] = ModelCfg(
        width_mult=0.5, conv="direct", act_bits=8
    )
    for hbits, htag in [(8, "8b"), (9, "8bh9")]:
        for base, btag in [("canonical", ""), ("legendre", "L-")]:
            for flex, ftag in [(False, "static"), (True, "flex")]:
                grid[f"t1-{btag}{ftag}-{htag}-w0.5"] = wcfg(0.5, base, flex, hbits)
    # Table 2: width 0.25, 8-bit only (0.5 columns reuse the t1 artifacts).
    grid["t2-direct-8b-w0.25"] = ModelCfg(
        width_mult=0.25, conv="direct", act_bits=8
    )
    for base, btag in [("canonical", ""), ("legendre", "L-")]:
        for flex, ftag in [(False, "static"), (True, "flex")]:
            grid[f"t2-{btag}{ftag}-8b-w0.25"] = wcfg(0.25, base, flex, 8)
    # Width-0.25 replica of Table 1's 9-bit-Hadamard row: on single-core
    # testbeds the w0.5 graphs are too slow to compile for a full table run,
    # so the T1 bench can fall back to the same grid at width 0.25
    # (WINOQ_T1_WIDTH=0.25; see rust/benches/table1_accuracy.rs).
    for base, btag in [("canonical", ""), ("legendre", "L-")]:
        for flex, ftag in [(False, "static"), (True, "flex")]:
            grid[f"t2-{btag}{ftag}-8bh9-w0.25"] = wcfg(0.25, base, flex, 9)
    return grid


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(tag: str, cfg: ModelCfg, outdir: str, seed: int = 0) -> None:
    names = model.param_names(cfg)
    params = resnet.init_params(cfg, seed=seed)
    p_specs = [
        jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names
    ]
    img_t = jax.ShapeDtypeStruct((TRAIN_BATCH, *IMAGE_SHAPE), jnp.float32)
    lab_t = jax.ShapeDtypeStruct((TRAIN_BATCH,), jnp.int32)
    img_e = jax.ShapeDtypeStruct((EVAL_BATCH, *IMAGE_SHAPE), jnp.float32)
    lab_e = jax.ShapeDtypeStruct((EVAL_BATCH,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    train = jax.jit(model.make_train_step(cfg))
    lowered = train.lower(p_specs, p_specs, img_t, lab_t, lr)
    with open(os.path.join(outdir, f"{tag}.train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    evalf = jax.jit(model.make_eval_step(cfg))
    lowered = evalf.lower(p_specs, img_e, lab_e)
    with open(os.path.join(outdir, f"{tag}.eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # Manifest: geometry + canonical param order. Space-separated text —
    # trivially parsed by rust/src/runtime/manifest.rs.
    with open(os.path.join(outdir, f"{tag}.manifest.txt"), "w") as f:
        f.write("winoq-manifest v1\n")
        f.write(f"variant {tag}\n")
        f.write(f"train_batch {TRAIN_BATCH}\n")
        f.write(f"eval_batch {EVAL_BATCH}\n")
        f.write(f"image {IMAGE_SHAPE[0]}x{IMAGE_SHAPE[1]}x{IMAGE_SHAPE[2]}\n")
        f.write(f"num_classes {cfg.num_classes}\n")
        for n in names:
            dims = "x".join(str(d) for d in params[n].shape)
            f.write(f"param {n} {dims}\n")

    # Init blob: params in canonical order, f32 little-endian (momentum is
    # all-zero and recreated rust-side).
    with open(os.path.join(outdir, f"{tag}.init.bin"), "wb") as f:
        for n in names:
            f.write(np.ascontiguousarray(params[n], np.float32).tobytes())
    print(f"  lowered {tag}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="output dir (default: ../artifacts)")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated substring filters on variant tags",
    )
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    outdir = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    os.makedirs(outdir, exist_ok=True)
    grid = variant_grid()
    if args.list:
        for tag in grid:
            print(tag)
        return
    filters = args.only.split(",") if args.only else None
    todo = {
        tag: cfg
        for tag, cfg in grid.items()
        if filters is None or any(f in tag for f in filters)
    }
    print(f"lowering {len(todo)} variants to {outdir}", flush=True)
    for tag, cfg in todo.items():
        # Skip when up to date (the Makefile also guards, belt+braces).
        marker = os.path.join(outdir, f"{tag}.manifest.txt")
        if os.path.exists(marker) and "--force" not in sys.argv:
            print(f"  {tag}: up to date", flush=True)
            continue
        lower_variant(tag, cfg, outdir)


if __name__ == "__main__":
    main()
