"""Pure-jnp oracles: direct convolution and reference Winograd tile math.

These are the correctness anchors for the Pallas kernel (L1) and the
vectorised Winograd layer (L2): `python/tests/test_kernel.py` hypothesis-
sweeps shapes against them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def direct_conv2d_nchw(x: jnp.ndarray, w: jnp.ndarray, padding: int = 0) -> jnp.ndarray:
    """Direct 2-D correlation: x [N,C,H,W], w [K,C,R,S] -> [N,K,H',W']."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def winograd_tile_ref(x_tile: jnp.ndarray, w: jnp.ndarray, mats: dict) -> jnp.ndarray:
    """Single-tile, single-channel 2-D Winograd correlation through the
    base-changed pipeline (paper eq. 4), all float32. x_tile (N_t,N_t),
    w (r,r); returns (m,m). Used to validate both the vectorised layer and
    the Pallas kernel on one tile."""
    g_p, bt_p, a_p = mats["g_p"], mats["bt_p"], mats["a_p"]
    p_inv, p_inv_t = mats["p_inv"], mats["p_inv_t"]
    ident = bool(mats["identity_base"])
    wt = g_p @ w @ g_p.T
    if not ident:
        wt = p_inv @ wt @ p_inv_t
    xt = x_tile if ident else p_inv_t @ x_tile @ p_inv
    xt = bt_p @ xt @ bt_p.T
    had = wt * xt
    if not ident:
        had = p_inv_t @ had @ p_inv
    return a_p.T @ had @ a_p


def extract_tiles(x: jnp.ndarray, n_t: int, m: int) -> jnp.ndarray:
    """x [N,C,H,W] -> overlapping tiles [N,C,TH,TW,n_t,n_t], stride m.

    Implemented as n_t x n_t static strided slices + stacks instead of a
    gather: gathers (and their scatter gradients) make XLA-CPU compilation
    of the train graph pathologically slow (minutes per layer), while
    slices/concats compile fast and differentiate to pad+add.
    """
    nb, c, h, w = x.shape
    th = (h - n_t) // m + 1
    tw = (w - n_t) // m + 1
    rows = []
    for i in range(n_t):
        cols = []
        for j in range(n_t):
            sl = jax.lax.slice(
                x,
                (0, 0, i, j),
                (nb, c, i + (th - 1) * m + 1, j + (tw - 1) * m + 1),
                (1, 1, m, m),
            )  # [N,C,TH,TW]
            cols.append(sl)
        rows.append(jnp.stack(cols, axis=-1))  # [N,C,TH,TW,n_t]
    return jnp.stack(rows, axis=-2)  # [N,C,TH,TW,n_t,n_t]


def scatter_tiles(y_tiles: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    """[N,K,TH,TW,m,m] -> [N,K,oh,ow] (crop the tile grid to the output)."""
    nb, k, th, tw, m, _ = y_tiles.shape
    y = y_tiles.transpose(0, 1, 2, 4, 3, 5).reshape(nb, k, th * m, tw * m)
    return y[:, :, :oh, :ow]
