"""L1 Pallas kernel: quantized Winograd F(m x m, 3 x 3) tile pipeline.

The paper's compute hot-spot — input transform, channel-accumulated
Hadamard product, output transform, with the Fig. 2 quantization casts —
as a single Pallas kernel.

TPU mapping (DESIGN.md §5): the grid walks tile rows so each program
instance holds one row of tiles for all channels in VMEM; the 6x6
transform constants are broadcast VMEM residents; the Hadamard-accumulate
over input channels is shaped as a (C -> K) contraction over the tile
axes — the MXU-friendly layout. On this image the kernel runs with
`interpret=True` (CPU PJRT cannot execute Mosaic custom-calls); the
real-TPU VMEM/MXU estimate is in DESIGN.md §7.

The kernel consumes pre-extracted tiles (overlapping windows cannot be
expressed by a non-overlapping BlockSpec); extraction happens in the
surrounding jitted function, so everything lowers into one HLO module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quant


def _tile_row_compute(x, u, bt_p, a_p, p_inv, p_inv_t, ident, hadamard_bits):
    """Tile-row math: x [C,TW,n,n] tiles, u [K,C,n,n] transformed weights
    -> [K,TW,m,m] output tiles. Implements the paper's eq. 4 staged
    pipeline with optional Fig. 2 quantization casts."""
    # Base change of the input tile: P^-T X P^-1.
    if not ident:
        x = jnp.einsum("ij,ctjq,ql->ctil", p_inv_t, x, p_inv)
    # Input transform: B_P^T X' B_P.
    xt = jnp.einsum("ij,ctjq,lq->ctil", bt_p, x, bt_p)
    if hadamard_bits is not None:
        xt = quant.fake_quant(xt, 8)
    # Hadamard product accumulated over input channels (general mults).
    acc = jnp.einsum("kcij,ctij->ktij", u, xt)
    if hadamard_bits is not None:
        acc = quant.fake_quant(acc, hadamard_bits)
    # Inverse base change + output transform: A_P^T (P^-T M P^-1) A_P.
    if not ident:
        acc = jnp.einsum("ij,ktjq,ql->ktil", p_inv_t, acc, p_inv)
    return jnp.einsum("ji,ktjq,ql->ktil", a_p, acc, a_p)


def transform_weights(w: jnp.ndarray, mats: dict, quantize: bool) -> jnp.ndarray:
    """Weight transform (amortised outside the kernel): canonical
    Winograd-domain weights via the base-changed route
    `P^-1 (G_P W G_P^T) P^-T` (paper eq. 2). w [K,C,r,r] -> [K,C,n,n]."""
    g_p = jnp.asarray(mats["g_p"], jnp.float32)
    p_inv = jnp.asarray(mats["p_inv"], jnp.float32)
    p_inv_t = jnp.asarray(mats["p_inv_t"], jnp.float32)
    u = jnp.einsum("ij,kcjl,ml->kcim", g_p, w, g_p)
    if not bool(mats["identity_base"]):
        u = jnp.einsum("ij,kcjq,ql->kcil", p_inv, u, p_inv_t)
    if quantize:
        u = quant.fake_quant(u, 8)
    return u


def winograd_conv_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mats: dict,
    *,
    m: int = 4,
    padding: int = 1,
    hadamard_bits: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Winograd conv via the Pallas tile kernel.

    x [N,C,H,W], w [K,C,r,r] -> [N,K,H',W'] (stride 1). When
    `hadamard_bits` is set, the Fig. 2 casts run inside the kernel
    (8-bit transforms, `hadamard_bits`-bit Hadamard accumulator).
    """
    from . import ref

    nb, c, h, wd = x.shape
    k, wc, r, _ = w.shape
    assert wc == c, "channel mismatch"
    n_t = m + r - 1
    oh = h + 2 * padding - r + 1
    ow = wd + 2 * padding - r + 1
    th = -(-oh // m)  # ceil div
    tw = -(-ow // m)
    # Pad so the tile grid covers the output exactly.
    ph = (th - 1) * m + n_t
    pw = (tw - 1) * m + n_t
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (0, 0),
            (padding, ph - h - padding),
            (padding, pw - wd - padding),
        ),
    )
    tiles = ref.extract_tiles(xp, n_t, m)  # [N,C,TH,TW,n,n]
    u = transform_weights(w, mats, hadamard_bits is not None)

    # Transform constants enter the kernel as (broadcast) inputs — Pallas
    # kernels may not capture traced constants.
    bt_p = jnp.asarray(mats["bt_p"], jnp.float32)
    a_p = jnp.asarray(mats["a_p"], jnp.float32)
    p_inv = jnp.asarray(mats["p_inv"], jnp.float32)
    p_inv_t = jnp.asarray(mats["p_inv_t"], jnp.float32)
    ident = bool(mats["identity_base"])

    def kernel(x_ref, u_ref, bt_ref, a_ref, pi_ref, pit_ref, o_ref):
        # x_ref block: [C, 1, TW, n, n] — one tile row, all channels.
        xrow = x_ref[...][:, 0]
        out = _tile_row_compute(
            xrow,
            u_ref[...],
            bt_ref[...],
            a_ref[...],
            pi_ref[...],
            pit_ref[...],
            ident,
            hadamard_bits,
        )
        o_ref[...] = out[:, None]

    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    def run_one_batch(tiles_b):
        # tiles_b: [C,TH,TW,n,n]; grid over tile rows.
        return pl.pallas_call(
            kernel,
            grid=(th,),
            in_specs=[
                pl.BlockSpec((c, 1, tw, n_t, n_t), lambda i: (0, i, 0, 0, 0)),
                full((k, c, n_t, n_t)),
                full((n_t, n_t)),
                full((n_t, m)),
                full((n_t, n_t)),
                full((n_t, n_t)),
            ],
            out_specs=pl.BlockSpec((k, 1, tw, m, m), lambda i: (0, i, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((k, th, tw, m, m), jnp.float32),
            interpret=interpret,
        )(tiles_b, u, bt_p, a_p, p_inv, p_inv_t)

    y_tiles = jax.vmap(run_one_batch)(tiles)  # [N,K,TH,TW,m,m]
    return ref.scatter_tiles(y_tiles, oh, ow)
