"""Symmetric fake-quantization with straight-through estimator (STE).

The quantization scheme of the paper's §4.2 (after Fernandez-Marques et
al. 2020): per-tensor symmetric scale `s = max|t| / (2^{b-1} - 1)`,
`q = clip(round(t/s), -qmax, qmax)`, dequantized back to `q*s`. The
backward pass is identity on the unclipped region (STE), so the
winograd-aware training graph differentiates through every cast of Fig. 2.

Build-time only (baked into the AOT'd train/eval steps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmax(bits: int) -> float:
    assert 2 <= bits <= 24, f"unsupported bit width {bits}"
    return float((1 << (bits - 1)) - 1)


def fake_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-tensor symmetric fake quantization with STE gradient.

    Scale is computed from the current tensor (dynamic quantization — the
    same semantics as the rust `Quantizer::calibrate` on every call).
    """
    qm = qmax(bits)
    maxabs = jnp.max(jnp.abs(x))
    scale = jnp.where(maxabs > 0, maxabs / qm, 1.0)
    scale = jax.lax.stop_gradient(scale)
    q = jnp.clip(jnp.round(x / scale), -qm, qm) * scale
    # STE: forward = q, backward = identity.
    return x + jax.lax.stop_gradient(q - x)


def fake_quant_static_scale(x: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    """Fake quantization with an externally supplied scale (e.g. a
    calibrated constant for matrices known ahead of time)."""
    qm = qmax(bits)
    q = jnp.clip(jnp.round(x / scale), -qm, qm) * scale
    return x + jax.lax.stop_gradient(q - x)


def quantize_codes(x: jnp.ndarray, bits: int):
    """(codes int32, scale) — the true-integer view, for tests that check
    agreement with the rust integer pipeline."""
    qm = qmax(bits)
    maxabs = jnp.max(jnp.abs(x))
    scale = jnp.where(maxabs > 0, maxabs / qm, 1.0)
    codes = jnp.clip(jnp.round(x / scale), -qm, qm).astype(jnp.int32)
    return codes, scale
