"""L2 train/eval steps: softmax cross-entropy, SGD with momentum.

These functions are what `aot.py` lowers to HLO text; the rust coordinator
executes them step after step with device-resident parameters. Parameters
and momentum buffers travel as flat lists in sorted-name order (the
manifest in `aot.py` records names/shapes so rust and python always agree).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import resnet
from .resnet import ModelCfg


def param_names(cfg: ModelCfg) -> list[str]:
    """Canonical (sorted) parameter order shared with the rust runtime."""
    return sorted(resnet.init_params(cfg, seed=0).keys())


def loss_and_acc(params: dict, images, labels, cfg: ModelCfg):
    logits = resnet.forward(params, images, cfg)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return nll, acc


def make_train_step(cfg: ModelCfg, momentum: float = 0.9, weight_decay: float = 5e-4):
    """Returns train_step(params_list, mom_list, images, labels, lr) ->
    (new_params_list, new_mom_list, loss, acc), all flat lists in
    `param_names(cfg)` order."""
    names = param_names(cfg)

    def train_step(params_list, mom_list, images, labels, lr):
        params = dict(zip(names, params_list))

        def loss_fn(p):
            return loss_and_acc(p, images, labels, cfg)

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params = []
        new_mom = []
        for name, mom in zip(names, mom_list):
            g = grads[name]
            if name.endswith(".w") or name == "fc.w":
                g = g + weight_decay * params[name]
            v = momentum * mom + g
            new_mom.append(v)
            new_params.append(params[name] - lr * v)
        return new_params, new_mom, loss, acc

    return train_step


def make_eval_step(cfg: ModelCfg):
    """eval_step(params_list, images, labels) -> (loss, correct_count)."""
    names = param_names(cfg)

    def eval_step(params_list, images, labels):
        params = dict(zip(names, params_list))
        logits = resnet.forward(params, images, cfg)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))
        return nll, correct

    return eval_step


def make_predict(cfg: ModelCfg):
    """predict(params_list, images) -> logits (serving entry point)."""
    names = param_names(cfg)

    def predict(params_list, images):
        params = dict(zip(names, params_list))
        return resnet.forward(params, images, cfg)

    return predict
